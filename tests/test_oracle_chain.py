"""The staged oracle hierarchy, differentially tested in ONE place:

    surrogate  →  packed  →  per-cell wavefront  →  event sim (θ = 1)

Every cell of the full scenario/network matrix (10 operator + 21 network
cells) runs through the same parametrized harness, each link of the chain
asserted against the next, stricter one:

* **surrogate vs packed** — on fresh seeded draws the training never saw,
  each cell's relative error stays inside its own stated calibrated bound
  for ≥ 85 % of draws (the bound is a held-out 95 % residual quantile with
  a 1.5× margin, so fresh coverage must stay near that level), and the
  matrix-wide median latency error is ≤ 2 % — the acceptance bar the
  serving threshold (``surrogate_max_err``) is calibrated against;
* **packed vs per-cell wavefront** — θ = 1 exact on operator cells, and
  allclose at random θ (tie-breaks in near-equal queue arrivals may
  legitimately differ between f32 evaluation orders; network totals are
  float32 compositions, hence the relative pin);
* **wavefront/packed vs event sim** — θ = 1 within each scenario's
  ``sim_tol`` (cycle-exact architectures: exact) and always within 1 %;
* **packed energy** — θ = 1 equals the analytic per-cell closed form
  E = Σ_k edyn_k + P_static · T from the raw op-class counts.

These asserts replace the pairwise agreement tests that used to be
duplicated across test_condense_packed.py, test_network.py, and
test_energy.py; the shared ``matrix_ex`` / ``matrix_surrogate`` session
fixtures live in conftest.py.
"""

import numpy as np
import pytest

from repro.core.aidg.explorer import default_scenarios, random_candidates
from repro.core.network import default_network_scenarios

OP_NAMES = [s.name for s in default_scenarios()]
NET_NAMES = [s.name for s in default_network_scenarios()]
ALL_NAMES = OP_NAMES + NET_NAMES

N_RAND = 6          # random-θ draws for the packed-vs-wavefront link
N_FRESH = 48        # fresh draws for the surrogate-vs-packed link
SEED = 20260808


@pytest.fixture(scope="module")
def packed_eval(matrix_ex):
    """θ = 1 plus seeded random candidates through ONE packed dispatch:
    ``(kt, (B, S) cycles, (B, S) energy)`` — the exact side of every
    agreement check below."""
    theta1 = np.ones((1, matrix_ex.space.n), np.float32)
    kt = np.concatenate([theta1, random_candidates(
        matrix_ex.space, N_RAND, seed=SEED, include_baseline=False)])
    cycles, energy = matrix_ex.evaluate_full(kt)
    return kt, cycles, energy


@pytest.fixture(scope="module")
def sur_report(matrix_ex, matrix_surrogate):
    """The surrogate's fresh-sample error report (draws the training and
    calibration never saw), shared by the per-cell and matrix-wide
    asserts."""
    from repro.surrogate import evaluate_surrogate
    return evaluate_surrogate(matrix_surrogate, matrix_ex, n=N_FRESH,
                              seed=SEED)


def _cell(matrix_ex, name):
    i = matrix_ex.scenario_names.index(name)
    return i, matrix_ex.compiled[i], matrix_ex._projections[i]


@pytest.mark.parametrize("name", ALL_NAMES)
def test_surrogate_within_stated_bound(name, matrix_ex, matrix_surrogate,
                                       sur_report):
    """Chain link 1: the fast tier is honest — fresh-sample errors stay
    inside the cell's own calibrated confidence bound at (near) the
    calibration quantile, for BOTH objectives."""
    i, _, _ = _cell(matrix_ex, name)
    bound = matrix_surrogate.err_bound[i]
    assert bound > 0.0, name
    e_lat = sur_report["err_latency"][:, i]
    e_en = sur_report["err_energy"][:, i]
    assert np.mean(e_lat <= bound) >= 0.85, (name, bound, np.sort(e_lat)[-5:])
    assert np.mean(e_en <= bound) >= 0.85, (name, bound, np.sort(e_en)[-5:])
    assert np.median(e_lat) <= bound, (name, bound, float(np.median(e_lat)))


@pytest.mark.parametrize("name", ALL_NAMES)
def test_packed_matches_percell_wavefront(name, matrix_ex, packed_eval):
    """Chain link 2: the packed single-dispatch result equals this cell's
    own wavefront evaluation — exact at θ = 1 on operator cells, within
    float32 tie-break tolerance at random θ and on network compositions."""
    i, cell, proj = _cell(matrix_ex, name)
    kt, cycles, _ = packed_eval
    wf = np.asarray(cell.evaluate(matrix_ex.space, kt, proj,
                                  engine="wavefront"), np.float64)
    packed = cycles[:, i].astype(np.float64)
    if name in OP_NAMES:
        assert packed[0] == wf[0], (name, packed[0], wf[0])
    else:
        assert packed[0] == pytest.approx(wf[0], rel=5e-3), name
    assert np.allclose(packed, wf, rtol=5e-3, atol=0.5), (
        name, packed, wf)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_wavefront_matches_event_sim_at_theta_one(name, matrix_ex,
                                                  packed_eval):
    """Chain link 3: at θ = 1 the analytic estimate agrees with the event
    simulator — the ground truth the whole hierarchy is anchored to —
    exactly on the sim_tol = 0 operator cells, within the stated sim_tol
    elsewhere, and within 1 % on every network cell (the end-to-end
    quantity the service actually ranks on)."""
    i, cell, _ = _cell(matrix_ex, name)
    _, cycles, _ = packed_eval
    est = float(cycles[0, i])
    sim = float(cell.simulate())
    tol = float(cell.scenario.sim_tol)
    rel = abs(est - sim) / sim
    if name in OP_NAMES and tol == 0.0:
        assert round(est) == round(sim), (name, est, sim)
    else:
        assert rel <= max(tol, 1e-3), (name, est, sim, rel)
    if name in NET_NAMES:
        assert rel <= 0.01, (name, est, sim, rel)


def test_packed_energy_matches_per_cell_recompute(matrix_ex, packed_eval):
    """Chain link 4: at θ = 1 the packed dispatch's energy equals the
    analytic per-cell closed form E = Σ_k edyn_k + P_static · T computed
    from the RAW per-problem op-class counts, on every cell, and the
    energy baselines normalize to exactly 1."""
    _, cycles, energy = packed_eval
    edyn, pstat = matrix_ex._energy_arrays()
    e_ref = edyn.sum(axis=1) + pstat * cycles[0].astype(np.float64)
    for k, cs in enumerate(matrix_ex.compiled):
        assert energy[0, k] == pytest.approx(e_ref[k], rel=1e-4), cs.name
    assert np.allclose(energy[0] / matrix_ex.energy_baselines, 1.0,
                       rtol=1e-6)


def test_matrix_wide_surrogate_acceptance(matrix_surrogate, sur_report):
    """The tentpole's acceptance bar: ≤ 2 % matrix-wide median latency
    error on held-out samples, and at that bound at most 30 % of cells
    are ineligible for the fast tier (the serving fallback ceiling)."""
    assert sur_report["median_latency_err"] <= 0.02, \
        sur_report["median_latency_err"]
    assert sur_report["median_energy_err"] <= 0.02, \
        sur_report["median_energy_err"]
    ineligible = np.mean(matrix_surrogate.err_bound > 0.02)
    assert ineligible <= 0.30, dict(zip(matrix_surrogate.cell_names,
                                        matrix_surrogate.err_bound))
