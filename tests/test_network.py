"""Network-level mapping (repro.core.network):

(a) per-layer AIDG makespans match the event-sim oracle per tile program
    (2+ networks x 2+ archs); the end-to-end θ = 1 vs composed-oracle
    check for every cell lives in tests/test_oracle_chain.py,
(b) composition semantics: sequential == Σ reps · layer makespans,
    pipelined ≤ sequential and ≥ every single layer,
(c) the per-(layer-shape, arch) compile cache: repeated layers compile
    once, shared tiles hit across networks,
(d) the DSE surface: network cells behave as Explorer cells (baseline
    normalization, knob sweeps, chunking) and the stacked grad sweep
    matches finite differences end-to-end.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config
from repro.core.aidg.dse import sweep
from repro.core.aidg.explorer import (DEFAULT_SPACE, Explorer,
                                      clear_scenario_cache,
                                      scenario_cache_stats)
from repro.core.network import (NETWORKS, NetworkScenario,
                                default_network_scenarios,
                                extract_layer_graph, lowerable_ops)

SCENARIOS = default_network_scenarios()
IDS = [s.name for s in SCENARIOS]

# θ = 1 end-to-end cycles per default cell, pinned against silent evaluator
# drift (same contract as GOLDEN_THETA1_CYCLES for operator cells; relative
# pin because network totals are float32 compositions).  Update only with a
# re-justified oracle check — the oracle-chain tier
# (tests/test_oracle_chain.py) re-derives the sim side on every run.
GOLDEN_E2E_THETA1 = {
    "oma/whisper_small": 9.2163109e+12,
    "systolic/whisper_small": 2.0121045e+12,
    "gamma/whisper_small": 1.0193998e+11,
    "eyeriss/whisper_small": 1.5446227e+11,
    "plasticine/whisper_small": 9.1819614e+10,
    "tpu_v5e/whisper_small": 1.7191464e+07,
    "oma/olmo_1b": 7.1448527e+10,
    "systolic/olmo_1b": 1.5598639e+10,
    "gamma/olmo_1b": 8.8078502e+08,
    "eyeriss/olmo_1b": 1.1975136e+09,
    "plasticine/olmo_1b": 7.1182234e+08,
    "tpu_v5e/olmo_1b": 5.3353780e+06,
    "oma/olmoe_1b_7b": 7.1562822e+10,
    "systolic/olmoe_1b_7b": 1.5623592e+10,
    "gamma/olmoe_1b_7b": 8.8229747e+08,
    "eyeriss/olmoe_1b_7b": 1.1994728e+09,
    "plasticine/olmoe_1b_7b": 7.1296102e+08,
    "tpu_v5e/olmoe_1b_7b": 2.2523700e+06,
    "gamma/falcon_mamba_7b": 4.9923226e+09,
    "plasticine/falcon_mamba_7b": 3.7337580e+09,
    "tpu_v5e/falcon_mamba_7b": 3.1134014e+07,
}


@pytest.fixture(scope="module")
def compiled():
    """Every default network cell, compiled once (shared AIDG cache)."""
    return {sc.name: sc.compile() for sc in SCENARIOS}


def _theta1(cn):
    return float(cn.evaluate(DEFAULT_SPACE, np.ones((1, 5), np.float32))[0])


# ---------------------------------------------------------------------------
# (a) oracle agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch,net", [("tpu_v5e", "whisper_small"), ("tpu_v5e", "olmo_1b"),
                 ("gamma", "whisper_small"), ("gamma", "olmo_1b")])
def test_per_layer_aidg_matches_event_sim(arch, net, compiled):
    """Per-layer check, 2 networks x 2 archs: every unique tile program's
    AIDG makespan vs its own event simulation."""
    cn = compiled[f"{arch}/{net}"]
    for cell in cn.cells:
        est = float(sweep(cell.problem,
                          np.ones((1, cell.problem.n_op), np.float32),
                          np.ones((1, cell.problem.n_st), np.float32))[0])
        sim = cell.simulate()
        tol = cell.scenario.sim_tol
        if tol == 0.0:
            assert round(est) == sim, (cn.name, cell.name, est, sim)
        else:
            assert abs(est - sim) / sim <= tol, (cn.name, cell.name, est, sim)


@pytest.mark.parametrize("scenario", SCENARIOS, ids=IDS)
def test_theta_one_golden_regression(scenario, compiled):
    assert scenario.name in GOLDEN_E2E_THETA1, (
        f"new network cell {scenario.name}: pin its θ=1 end-to-end cycles")
    est = _theta1(compiled[scenario.name])
    assert est == pytest.approx(GOLDEN_E2E_THETA1[scenario.name], rel=1e-4)


def test_matrix_extent():
    """The matrix spans the 4 assigned networks across all 6 architectures
    (cells whose operators don't lower are absent, e.g. selective scan on
    the systolic array)."""
    nets = {s.network for s in SCENARIOS}
    archs = {s.arch for s in SCENARIOS}
    assert nets == set(NETWORKS) and len(nets) >= 4
    assert len(archs) == 6
    assert len(SCENARIOS) >= 14
    names = {s.name for s in SCENARIOS}
    assert "systolic/falcon_mamba_7b" not in names   # no scan lowering
    assert "scan" not in lowerable_ops("systolic")


def test_layer_graph_consistency_all_configs():
    """The expansion agrees with extract_operators for every assigned
    config (the constructor raises on any count mismatch)."""
    from repro.models.config import SHAPES
    for arch_id in all_arch_ids():
        cfg = get_config(arch_id)
        for shape in (SHAPES["train_4k"], SHAPES["decode_32k"]):
            lg = extract_layer_graph(cfg, shape)
            assert len(lg.instances) > cfg.n_layers
            assert sum(n for _, n in lg.runs) == len(lg.instances)
            assert len(lg.unique) <= len(lg.instances)
            assert set(lg.counts()) == set(range(len(lg.unique)))


# ---------------------------------------------------------------------------
# (b) composition semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["tpu_v5e/olmo_1b", "gamma/whisper_small"])
def test_sequential_compose_equals_sum_of_layers(name, compiled):
    """Sequential end-to-end == Σ (total instances · per-layer makespan),
    for θ = 1 and for a non-trivial knob vector."""
    cn = compiled[name]
    for kt in (np.ones((1, 5), np.float32),
               np.asarray([[0.5, 2.0, 0.8, 1.5, 1.0]], np.float32)):
        e2e = float(cn.evaluate(DEFAULT_SPACE, kt)[0])
        per_layer = []
        for prob in cn.stack.problems:
            to, ts = DEFAULT_SPACE.theta_for(prob, kt)
            per_layer.append(float(sweep(prob, to, ts)[0]))
        total = float((cn.reps_per_layer * np.asarray(per_layer)).sum())
        assert e2e == pytest.approx(total, rel=1e-5), (name, e2e, total)


@pytest.mark.parametrize("name", ["tpu_v5e/olmo_1b", "gamma/olmo_1b",
                                  "tpu_v5e/whisper_small"])
def test_pipelined_bounded_by_sequential_and_layers(name, compiled):
    seq = compiled[name]
    sc = seq.scenario
    pip = NetworkScenario(sc.arch, sc.network, sc.shape, "pipelined").compile()
    for kt in (np.ones((1, 5), np.float32),
               np.asarray([[0.5, 2.0, 0.8, 1.5, 1.0]], np.float32)):
        s = float(seq.evaluate(DEFAULT_SPACE, kt)[0])
        p = float(pip.evaluate(DEFAULT_SPACE, kt)[0])
        assert p <= s * (1 + 1e-6), (name, p, s)
        # never faster than any single constituent layer
        for prob in pip.stack.problems:
            to, ts = DEFAULT_SPACE.theta_for(prob, kt)
            assert p >= float(sweep(prob, to, ts)[0]) - 1e-3
    # overlap must actually be credited somewhere in the default matrix
    s1 = float(seq.evaluate(DEFAULT_SPACE, np.ones((1, 5), np.float32))[0])
    p1 = float(pip.evaluate(DEFAULT_SPACE, np.ones((1, 5), np.float32))[0])
    if name == "tpu_v5e/olmo_1b":
        assert p1 < s1, "double-buffer overlap credited nothing"


def test_pipelined_mode_rejects_unknown():
    with pytest.raises(ValueError, match="mode"):
        NetworkScenario("gamma", "olmo_1b", mode="overlapped")


# ---------------------------------------------------------------------------
# (c) compile-cache behavior
# ---------------------------------------------------------------------------


def test_repeated_layers_compile_once_and_share_across_networks():
    clear_scenario_cache()
    cn1 = NetworkScenario("gamma", "olmo_1b").compile()
    s1 = scenario_cache_stats()
    # olmo on gamma = 2 unique tile programs (gemm + attention) even though
    # the network runs 81 layer instances
    assert cn1.n_layers == 2
    assert len(cn1.layer_graph.instances) == 81
    assert s1["misses"] == 2
    # same-shape layers inside the network never re-enter compile_scenario;
    # a second compile of the same cell is pure cache hits
    NetworkScenario("gamma", "olmo_1b").compile()
    s2 = scenario_cache_stats()
    assert s2["misses"] == s1["misses"]
    assert s2["hits"] == s1["hits"] + 2
    # another network on the same arch reuses the shared tiles (olmoe adds
    # no new gamma tiles: gemm + attention again)
    NetworkScenario("gamma", "olmoe_1b_7b").compile()
    s3 = scenario_cache_stats()
    assert s3["misses"] == s2["misses"]
    # an arch with per-shape programs misses once per unique layer shape
    cn4 = NetworkScenario("tpu_v5e", "olmo_1b").compile()
    s4 = scenario_cache_stats()
    assert s4["misses"] == s3["misses"] + cn4.n_layers


# ---------------------------------------------------------------------------
# (d) the DSE surface
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def net_explorer():
    """A small mixed-arch, network-only explorer for DSE-surface tests."""
    return Explorer(scenarios=default_network_scenarios(
        networks=["olmo_1b"], archs=["tpu_v5e", "gamma"]))


def test_explorer_networks_kwarg():
    """``Explorer(networks=[...])`` appends the named networks' cells to
    the requested operator cells (True would append the full matrix)."""
    from repro.core.aidg.explorer import default_scenarios
    ops = default_scenarios()[:1]
    ex = Explorer(scenarios=ops, networks=["falcon_mamba_7b"])
    assert ex.scenario_names[0] == ops[0].name
    nets = ex.scenario_names[1:]
    assert nets == ["gamma/falcon_mamba_7b", "plasticine/falcon_mamba_7b",
                    "tpu_v5e/falcon_mamba_7b"]


def test_network_sweep_mode_validation(compiled):
    from repro.core.aidg.dse import (compiled_network_sweep,
                                     grad_network_sweep)
    cn = compiled["gamma/olmo_1b"]
    with pytest.raises(ValueError, match="mode"):
        compiled_network_sweep(cn.stack, mode="nope")
    with pytest.raises(ValueError, match="mode"):
        grad_network_sweep(cn.stack, cn.projection(DEFAULT_SPACE),
                           mode="nope")


def test_pipelined_single_run_stack(compiled):
    """whisper on eyeriss collapses to ONE tile run (every layer shares the
    conv proxy), exercising the no-between-runs composition branch."""
    pip = NetworkScenario("eyeriss", "whisper_small",
                          mode="pipelined").compile()
    assert len(pip.stack.run_layer) == 1
    kt = np.ones((1, 5), np.float32)
    p = float(pip.evaluate(DEFAULT_SPACE, kt)[0])
    s = float(compiled["eyeriss/whisper_small"].evaluate(DEFAULT_SPACE,
                                                         kt)[0])
    assert 0 < p <= s * (1 + 1e-6)


def test_network_cells_as_explorer_cells(net_explorer):
    ex = net_explorer
    assert ex.scenario_names == ["tpu_v5e/olmo_1b", "gamma/olmo_1b"]
    res = ex.explore(np.ones((1, ex.space.n), np.float32))
    assert res.latency[0] == pytest.approx(1.0, abs=1e-5)
    cand = np.stack([np.ones(5), [0.5, 0.5, 0.5, 0.5, 0.5]]).astype(np.float32)
    res = ex.explore(cand)
    # uniformly faster hardware -> faster network, higher cost
    assert np.all(res.cycles[1] < res.cycles[0])
    assert res.cost[1] > res.cost[0]
    rows = ex.level_stats()
    assert all(r["n"] >= r["levels"] >= 1 for r in rows)


def test_chunked_network_evaluate_matches(net_explorer):
    cn = net_explorer.compiled[1]  # gamma/olmo_1b
    rng = np.random.default_rng(11)
    kt = rng.uniform(0.5, 2.0, (13, 5)).astype(np.float32)
    full = cn.evaluate(DEFAULT_SPACE, kt)
    chunked = cn.evaluate(DEFAULT_SPACE, kt, chunk=4)
    assert np.allclose(full, chunked, rtol=1e-6)


def test_grad_network_matches_finite_differences(net_explorer):
    """End-to-end d(soft network latency)/d(knob) vs central differences,
    and τ → 0 convergence of soft to hard (sequential soft ≥ hard)."""
    cn = net_explorer.compiled[1]  # gamma/olmo_1b
    proj = cn.projection(DEFAULT_SPACE)
    fn = cn.grad_fn(proj, n_iters=net_explorer.n_iters)
    k0 = np.asarray([[0.8, 1.2, 0.9, 1.1, 1.0]], np.float32)
    tau = 0.05
    v, g = fn(jnp.asarray(k0), jnp.float32(tau))
    g = np.asarray(g, np.float64)[0]
    eps = 1e-3
    for i in range(5):
        kp, km = k0.copy(), k0.copy()
        kp[0, i] += eps
        km[0, i] -= eps
        vp, _ = fn(jnp.asarray(kp), jnp.float32(tau))
        vm, _ = fn(jnp.asarray(km), jnp.float32(tau))
        fd = (float(vp[0]) - float(vm[0])) / (2 * eps)
        assert g[i] == pytest.approx(fd, rel=0.05, abs=1e-3), (i, g[i], fd)
    hard = float(cn.evaluate(DEFAULT_SPACE, k0)[0])
    soft, _ = fn(jnp.asarray(k0), jnp.float32(0.01))
    assert float(soft[0]) >= hard - 1e-3
    assert float(soft[0]) <= hard * 1.005


def test_gradient_refine_on_network_matrix(net_explorer):
    """GradientExplorer descends end-to-end network latency·cost: a short
    multi-start run must not regress from the θ = 1 reference design."""
    from repro.core.aidg.gradient import GradientExplorer
    ge = GradientExplorer(net_explorer)
    res = ge.refine(starts=2, steps=6, seed=0)
    base = float(ge.hard_score(np.ones((1, 5), np.float32))[0])
    assert res.score <= base + 1e-6
