"""Quickstart: train a small LM end-to-end on CPU, then estimate its step
time on modeled accelerators with ACADL.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_smoke_config
from repro.core.aidg import estimate_cycles
from repro.core.archs import TPU_V5E, make_tpu_v5e_ag
from repro.core.mapping.workload import map_to_tpu
from repro.launch.train import train_loop
from repro.models import SHAPES
from repro.models.config import ShapeConfig


def main():
    # --- 1. train a reduced olmo-style model for a few hundred steps ------
    cfg = get_smoke_config("olmo-1b")
    print(f"training {cfg.arch_id} (smoke config, "
          f"{cfg.n_params()/1e6:.1f}M params) ...")
    params, metrics = train_loop(cfg, steps=200, batch=8, seq=128,
                                 ckpt_dir="/tmp/quickstart_ckpt",
                                 ckpt_every=100)
    losses = [r["loss"] for r in metrics.rows]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    # --- 2. ACADL: how fast would the FULL olmo-1b train on a TPU-v5e? ----
    from repro.configs import get_config
    full = get_config("olmo-1b")
    shape = SHAPES["train_4k"]
    ag, _ = make_tpu_v5e_ag()
    prog = map_to_tpu(full, shape, per_device=256)
    cycles, _ = estimate_cycles(ag, prog)
    secs = cycles / (TPU_V5E["clock_ghz"] * 1e9)
    print(f"ACADL estimate: {full.arch_id} {shape.name} on 256 modeled "
          f"v5e chips: {secs*1e3:.1f} ms/step")


if __name__ == "__main__":
    main()
