"""Quickstart: train a small LM end-to-end on CPU, then estimate its step
time on modeled accelerators with ACADL — per fused operator and for the
whole network.

    PYTHONPATH=src python examples/quickstart.py            # full demo
    PYTHONPATH=src python examples/quickstart.py --steps 20 # CI smoke
"""

import argparse

from repro.configs import get_config, get_smoke_config
from repro.core.aidg import estimate_cycles
from repro.core.archs import TPU_V5E, make_tpu_v5e_ag
from repro.core.mapping.workload import map_to_tpu
from repro.launch.train import train_loop
from repro.models import SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200,
                    help="training steps (use a small value for CI smoke)")
    args = ap.parse_args()

    # --- 1. train a reduced olmo-style model ------------------------------
    cfg = get_smoke_config("olmo-1b")
    print(f"training {cfg.arch_id} (smoke config, "
          f"{cfg.n_params()/1e6:.1f}M params, {args.steps} steps) ...")
    params, metrics = train_loop(cfg, steps=args.steps, batch=8, seq=128,
                                 ckpt_dir="/tmp/quickstart_ckpt",
                                 ckpt_every=max(10, args.steps // 2))
    losses = [r["loss"] for r in metrics.rows]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    # --- 2. ACADL: how fast would the FULL olmo-1b train on a TPU-v5e? ----
    full = get_config("olmo-1b")
    shape = SHAPES["train_4k"]
    ag, _ = make_tpu_v5e_ag()
    prog = map_to_tpu(full, shape, per_device=256)
    cycles, _ = estimate_cycles(ag, prog)
    secs = cycles / (TPU_V5E["clock_ghz"] * 1e9)
    print(f"ACADL estimate: {full.arch_id} {shape.name} on 256 modeled "
          f"v5e chips: {secs*1e3:.1f} ms/step")

    # --- 3. network-level mapping: the whole DNN as a layer graph ---------
    # lower olmo-1b layer-by-layer onto the modeled TPU and compose the
    # per-layer AIDG makespans in max-plus (repro.core.network)
    import numpy as np
    from repro.core.aidg.explorer import DEFAULT_SPACE
    from repro.core.network import NetworkScenario

    for mode in ("sequential", "pipelined"):
        cn = NetworkScenario("tpu_v5e", "olmo_1b", mode=mode).compile()
        e2e = float(cn.evaluate(DEFAULT_SPACE,
                                np.ones((1, DEFAULT_SPACE.n), np.float32))[0])
        ms = e2e / (TPU_V5E["clock_ghz"] * 1e9) * 1e3
        print(f"network-level ({mode}): "
              f"{len(cn.layer_graph.instances)} layer instances -> "
              f"{cn.n_layers} unique AIDG programs, {e2e:.3e} cycles "
              f"({ms:.2f} ms) end-to-end decode step")


if __name__ == "__main__":
    main()
