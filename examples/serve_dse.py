"""DSE-as-a-service load harness: many concurrent clients asking "which
accelerator + config for my workload?" against ONE shared
:class:`repro.serve.DSEService` — the ROADMAP's serving story, end to
end.

Fires ``--clients`` threads over a mixed query stream (full-matrix,
arch-subset, knob-override and top-k queries, each distinct question
asked ``--repeats`` times), prints the served recommendations and the
service counters, then exits non-zero unless

* the answer cache actually hit (hit ratio > 0 — repeated questions
  must never reach the device twice), and
* the device-sharded evaluator agrees bitwise with the single-device
  path on the service's candidate pool.

This is the CI ``serve-smoke`` gate; force a multi-device host CPU with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/serve_dse.py --budget small

``--faults`` switches to the chaos harness (the CI ``chaos-smoke``
gate): the same concurrent load is fired at a service whose packed
dispatch is being killed by a deterministic fault plan
(``SERVE_FAULT_PLAN`` or a built-in window of transient errors, a
poisoned payload, and a worker kill), with the surrogate tier armed for
degradation.  It exits non-zero unless

* zero queries are lost or duplicated — every submission resolves to
  exactly one outcome: an answer to its own question or a structured
  error,
* the circuit breaker actually opened under the faults AND recovered —
  the post-chaos service answers ``tier="packed"`` again, and
* every ``surrogate-degraded`` answer is within its stated widened
  error bound of the packed oracle recomputed offline.
"""

import argparse
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.aidg.explorer import Explorer
from repro.serve import (CircuitBreaker, DSEService, FaultPlan, Query,
                         RetryPolicy, ServeError, WorkerKill)
from repro.serve.faults import ENV_FAULT_PLAN

# the built-in chaos window: retries absorb the first error, the second
# dispatch exhausts its budget (breaker trips), then a poisoned payload
# and a worker-thread kill keep the oracle dead before the plan runs dry
# and the half-open probe recovers
DEFAULT_FAULT_PLAN = ("packed[0:4]=error;packed[4]=poison;"
                      "packed[5]=kill;packed[6:8]=error")


def build_stream(ex, repeats):
    """The client workload: every served workload asked three ways
    (full matrix, top-3, with a pinned knob), every arch asked for its
    own profile — repeated so the cache has something to hit."""
    workloads = sorted({cs.workload for cs in ex.compiled})
    archs = sorted({cs.arch for cs in ex.compiled})
    knob = ex.space.names[0]
    distinct = []
    for w in workloads:
        distinct += [Query.make(workload=w),
                     Query.make(workload=w, top_k=3),
                     Query.make(workload=w, overrides={knob: 2.0})]
    distinct += [Query.make(archs=[a]) for a in archs]
    return distinct, distinct * repeats


def run_faults(args):
    """The chaos harness (CI ``chaos-smoke``): concurrent load against a
    fault-injected service, then the three gates from the module
    docstring — zero lost queries, breaker opened AND recovered,
    degraded answers honest about their widened bounds."""
    spec = os.environ.get(ENV_FAULT_PLAN) or DEFAULT_FAULT_PLAN
    plan = FaultPlan.parse(spec)
    if plan.max_faulty_attempt() < 0:
        print(f"FAIL: fault plan {spec!r} never ends — the breaker could "
              f"not recover", file=sys.stderr)
        return 1

    t0 = time.perf_counter()
    ex = Explorer()
    print(f"compiled matrix: {len(ex.compiled)} cells, "
          f"{ex.space.n} knobs ({time.perf_counter() - t0:.1f}s)")

    from repro.surrogate import SurrogateConfig, train_surrogate
    t0 = time.perf_counter()
    bundle = train_surrogate(ex, SurrogateConfig(
        n_samples=64 if args.budget == "small" else 128,
        steps=400 if args.budget == "small" else 1000))
    # cover roughly the better-calibrated half of the matrix, so the
    # chaos run exercises BOTH rungs of the degradation ladder: covered
    # queries degrade, uncovered ones fail fast
    degraded_max_err = float(np.median(bundle.err_bound))
    print(f"surrogate trained in {time.perf_counter() - t0:.1f}s; "
          f"degraded coverage bound {degraded_max_err:.3f} "
          f"({int(np.sum(bundle.err_bound <= degraded_max_err))}/"
          f"{len(bundle.err_bound)} cells)")

    pool = 32 if args.budget == "small" else 128
    repeats = args.repeats or (3 if args.budget == "small" else 8)
    distinct, stream = build_stream(ex, repeats)
    print(f"fault plan: {plan.to_spec()}")

    svc = DSEService(ex, pool=pool, chunk=pool, max_batch=8,
                     window_s=0.005, surrogate=bundle,
                     surrogate_max_err=-1.0,     # packed unless degraded
                     retry=RetryPolicy(max_attempts=2, base_s=0.001),
                     breaker=CircuitBreaker(open_after=1, probe_after=1),
                     fault_plan=plan, degraded_max_err=degraded_max_err)
    ok = True
    try:
        def ask(q):
            try:
                return svc.query(q, timeout=120.0)
            except ServeError as e:
                return e
            except WorkerKill as e:
                return e

        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.clients) as tp:
            outcomes = list(tp.map(ask, stream))
        dt = time.perf_counter() - t0

        st = svc.stats()
        answers = [o for o in outcomes if not isinstance(o, BaseException)]
        errors = [o for o in outcomes if isinstance(o, BaseException)]
        by_tier = {}
        for a in answers:
            by_tier[a.tier] = by_tier.get(a.tier, 0) + 1
        print(f"\n{len(stream)} queries from {args.clients} clients in "
              f"{dt:.2f}s under chaos: {len(answers)} answered "
              f"{by_tier}, {len(errors)} failed structurally "
              f"({sorted(set(type(e).__name__ for e in errors))}); "
              f"retries={st['retries']} worker_restarts="
              f"{st['worker_restarts']} breaker={st['breaker']['state']} "
              f"opens={st['breaker']['opens']}")

        # gate 1: zero lost / duplicated queries, each answer its own
        if len(outcomes) != len(stream):
            print(f"FAIL: {len(stream)} submitted, {len(outcomes)} "
                  f"resolved", file=sys.stderr)
            ok = False
        mismatched = sum(1 for q, o in zip(stream, outcomes)
                         if not isinstance(o, BaseException)
                         and o.query != q)
        if mismatched:
            print(f"FAIL: {mismatched} answers do not match their own "
                  f"query (reorder/swap)", file=sys.stderr)
            ok = False

        # gate 2: the breaker opened under the faults AND recovers —
        # walk the shed->probe cycle until a packed answer comes back
        if st["breaker"]["opens"] < 1:
            print("FAIL: the fault plan never tripped the circuit "
                  "breaker", file=sys.stderr)
            ok = False
        probe = Query.make(workload=distinct[0].workload, top_k=17)
        recovered = None
        for _ in range(2 * plan.max_faulty_attempt() + 4):
            try:
                recovered = svc.query(probe, timeout=120.0)
                break
            except (ServeError, WorkerKill):
                continue
        if recovered is None or recovered.tier != "packed":
            print(f"FAIL: breaker never recovered to the packed tier "
                  f"(last state {svc.breaker.state})", file=sys.stderr)
            ok = False
        else:
            print(f"breaker recovered: {svc.breaker.transitions} -> "
                  f"tier={recovered.tier}")

        # gate 3: degraded answers honest within their widened bounds,
        # against the packed oracle recomputed offline (no faults)
        degraded = {a.query.key: a for a in answers
                    if a.tier == "surrogate-degraded"}
        if degraded:
            # fault_plan="" explicitly DISARMS injection for the oracle
            # service — without it the SERVE_FAULT_PLAN env hook would
            # poison the recompute too
            with DSEService(ex, pool=pool, chunk=pool,
                            max_batch=8, fault_plan="") as clean:
                exact = clean.query_many([a.query
                                          for a in degraded.values()])
            worst = 0.0
            for a, e in zip(degraded.values(), exact):
                pool_lat = {d.theta: d.latency for d in e.designs}
                for d in a.designs:
                    if d.theta not in pool_lat:
                        continue        # tiers may rank different rows
                    rel = abs(d.latency - pool_lat[d.theta]) \
                        / pool_lat[d.theta]
                    worst = max(worst, rel / a.err_bound)
                    if rel > a.err_bound:
                        print(f"FAIL: degraded answer for "
                              f"{a.query.workload!r} off by {rel:.3f} "
                              f"> stated bound {a.err_bound:.3f}",
                              file=sys.stderr)
                        ok = False
            print(f"{len(degraded)} distinct degraded answers checked "
                  f"against the offline packed oracle (worst error at "
                  f"{worst:.2f} of the stated bound)")
        elif st["tiers"]["surrogate-degraded"] == 0:
            print("FAIL: chaos run produced no degraded answers — the "
                  "plan never exercised the degradation ladder",
                  file=sys.stderr)
            ok = False
    finally:
        svc.close()

    if not ok:
        return 1
    print("chaos-smoke gates passed")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", choices=("small", "full"),
                    default=os.environ.get("BENCH_BUDGET", "small")
                    or "small")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=None,
                    help="times each distinct query is asked "
                         "(default: 3 small / 8 full)")
    ap.add_argument("--faults", action="store_true",
                    help="run the chaos harness: inject the "
                         f"${ENV_FAULT_PLAN} fault plan (or the built-in "
                         "default) and assert the failure-semantics gates")
    args = ap.parse_args(argv)
    if args.faults:
        return run_faults(args)
    pool = 32 if args.budget == "small" else 128
    repeats = args.repeats or (3 if args.budget == "small" else 8)

    t0 = time.perf_counter()
    ex = Explorer()
    print(f"compiled matrix: {len(ex.compiled)} cells, "
          f"{ex.space.n} knobs ({time.perf_counter() - t0:.1f}s)")
    distinct, stream = build_stream(ex, repeats)

    with DSEService(ex, pool=pool, chunk=pool, max_batch=8,
                    window_s=0.005) as svc:
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.clients) as tp:
            answers = list(tp.map(svc.query, stream))
        dt = time.perf_counter() - t0
        st = svc.stats()

    print(f"\n{len(stream)} queries from {args.clients} clients in "
          f"{dt:.2f}s ({len(stream) / dt:.0f} q/s), coalesced into "
          f"{st['windows']} windows / {st['device_dispatches']} device "
          f"dispatches (mean batch {st['mean_batch']:.1f})")

    print("\nserved recommendations (one per distinct question):")
    seen = set()
    for a in answers:
        if a.query.key in seen:
            continue
        seen.add(a.query.key)
        d = a.best
        what = a.query.workload or f"archs={list(a.query.archs)}"
        pins = ",".join(f"{k}={v:g}" for k, v in a.query.overrides)
        print(f"  {what:14s} {'[' + pins + ']' if pins else '':14s}"
              f"-> {a.best_arch:10s} latency {d.latency:.3f} "
              f"cost {d.cost:.2f} ({len(a.designs)} Pareto designs over "
              f"{len(a.cells)} cells)")

    cs = st["cache"]
    print(f"\ncache: {cs['hits']} hits + {cs['coalesced']} coalesced / "
          f"{cs['misses']} misses (hit ratio {st['hit_ratio']:.2f}); "
          f"{st['dispatched_candidates']} candidate rows evaluated "
          f"device-side")

    # -- the two serve-smoke gates -----------------------------------------
    ok = True
    if st["hit_ratio"] <= 0.0:
        print("FAIL: answer cache never hit", file=sys.stderr)
        ok = False

    pm = ex.packed_matrix()
    devices = pm.n_shards(None)
    cand = svc.pool
    single = pm.evaluate(cand)
    shard = pm.evaluate(cand, sharded=True)
    exact = bool(np.array_equal(single, shard))
    print(f"sharded check: {devices} device(s), "
          f"bitwise agreement = {exact}")
    if not exact:
        print("FAIL: sharded evaluation diverges from single-device",
              file=sys.stderr)
        ok = False

    if not ok:
        return 1
    print("serve-smoke gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
