"""DSE-as-a-service load harness: many concurrent clients asking "which
accelerator + config for my workload?" against ONE shared
:class:`repro.serve.DSEService` — the ROADMAP's serving story, end to
end.

Fires ``--clients`` threads over a mixed query stream (full-matrix,
arch-subset, knob-override and top-k queries, each distinct question
asked ``--repeats`` times), prints the served recommendations and the
service counters, then exits non-zero unless

* the answer cache actually hit (hit ratio > 0 — repeated questions
  must never reach the device twice), and
* the device-sharded evaluator agrees bitwise with the single-device
  path on the service's candidate pool.

This is the CI ``serve-smoke`` gate; force a multi-device host CPU with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/serve_dse.py --budget small
"""

import argparse
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.aidg.explorer import Explorer
from repro.serve import DSEService, Query


def build_stream(ex, repeats):
    """The client workload: every served workload asked three ways
    (full matrix, top-3, with a pinned knob), every arch asked for its
    own profile — repeated so the cache has something to hit."""
    workloads = sorted({cs.workload for cs in ex.compiled})
    archs = sorted({cs.arch for cs in ex.compiled})
    knob = ex.space.names[0]
    distinct = []
    for w in workloads:
        distinct += [Query.make(workload=w),
                     Query.make(workload=w, top_k=3),
                     Query.make(workload=w, overrides={knob: 2.0})]
    distinct += [Query.make(archs=[a]) for a in archs]
    return distinct, distinct * repeats


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", choices=("small", "full"),
                    default=os.environ.get("BENCH_BUDGET", "small")
                    or "small")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=None,
                    help="times each distinct query is asked "
                         "(default: 3 small / 8 full)")
    args = ap.parse_args(argv)
    pool = 32 if args.budget == "small" else 128
    repeats = args.repeats or (3 if args.budget == "small" else 8)

    t0 = time.perf_counter()
    ex = Explorer()
    print(f"compiled matrix: {len(ex.compiled)} cells, "
          f"{ex.space.n} knobs ({time.perf_counter() - t0:.1f}s)")
    distinct, stream = build_stream(ex, repeats)

    with DSEService(ex, pool=pool, chunk=pool, max_batch=8,
                    window_s=0.005) as svc:
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.clients) as tp:
            answers = list(tp.map(svc.query, stream))
        dt = time.perf_counter() - t0
        st = svc.stats()

    print(f"\n{len(stream)} queries from {args.clients} clients in "
          f"{dt:.2f}s ({len(stream) / dt:.0f} q/s), coalesced into "
          f"{st['windows']} windows / {st['device_dispatches']} device "
          f"dispatches (mean batch {st['mean_batch']:.1f})")

    print("\nserved recommendations (one per distinct question):")
    seen = set()
    for a in answers:
        if a.query.key in seen:
            continue
        seen.add(a.query.key)
        d = a.best
        what = a.query.workload or f"archs={list(a.query.archs)}"
        pins = ",".join(f"{k}={v:g}" for k, v in a.query.overrides)
        print(f"  {what:14s} {'[' + pins + ']' if pins else '':14s}"
              f"-> {a.best_arch:10s} latency {d.latency:.3f} "
              f"cost {d.cost:.2f} ({len(a.designs)} Pareto designs over "
              f"{len(a.cells)} cells)")

    cs = st["cache"]
    print(f"\ncache: {cs['hits']} hits + {cs['coalesced']} coalesced / "
          f"{cs['misses']} misses (hit ratio {st['hit_ratio']:.2f}); "
          f"{st['dispatched_candidates']} candidate rows evaluated "
          f"device-side")

    # -- the two serve-smoke gates -----------------------------------------
    ok = True
    if st["hit_ratio"] <= 0.0:
        print("FAIL: answer cache never hit", file=sys.stderr)
        ok = False

    pm = ex.packed_matrix()
    devices = pm.n_shards(None)
    cand = svc.pool
    single = pm.evaluate(cand)
    shard = pm.evaluate(cand, sharded=True)
    exact = bool(np.array_equal(single, shard))
    print(f"sharded check: {devices} device(s), "
          f"bitwise agreement = {exact}")
    if not exact:
        print("FAIL: sharded evaluation diverges from single-device",
              file=sys.stderr)
        ok = False

    if not ok:
        return 1
    print("serve-smoke gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
