"""Walk through the paper's three modeling examples end-to-end:

  §4.1  One MAC Accelerator (OMA)       — scalar level, Listing 5 GeMM
  §4.2  Parameterizable systolic array  — templates + dangling edges
  §4.3  Γ̈ [gœna]                        — fused-tensor level, Listing 4

and §6's timing simulation + the AIDG fast path ([16]).

    PYTHONPATH=src python examples/paper_walkthrough.py
"""

import numpy as np

from repro.core.acadl import simulate
from repro.core.aidg import estimate_cycles
from repro.core.archs import make_gamma_ag, make_oma_ag, make_systolic_ag
from repro.core.mapping.gemm import (gamma_gemm, init_gemm_memory,
                                     oma_gemm_looped, oma_gemm_unrolled,
                                     read_gemm_result)
from repro.core.mapping.systolic import (init_systolic_memory,
                                         read_systolic_result,
                                         systolic_gemm_program)


def main():
    rng = np.random.default_rng(0)
    A = rng.integers(-3, 4, (8, 8)).astype(float)
    B = rng.integers(-3, 4, (8, 8)).astype(float)

    # --- §4.1 OMA ----------------------------------------------------------
    print("== OMA (scalar level, paper §4.1) ==")
    ag, _ = make_oma_ag()
    init_gemm_memory(ag, A, B)
    res = simulate(ag, oma_gemm_looped(8, 8, 8))
    ok = np.array_equal(read_gemm_result(ag, 8, 8), A @ B)
    print(f"  looped GeMM (Listing 5):   {res.cycles:6d} cycles  correct={ok}")
    ag, _ = make_oma_ag()
    init_gemm_memory(ag, A, B)
    res2 = simulate(ag, oma_gemm_unrolled(8, 8, 8, 4, 4, 4))
    print(f"  tiled/unrolled GeMM:       {res2.cycles:6d} cycles "
          f"({res.cycles / res2.cycles:.1f}x fewer)")

    # --- §4.2 systolic array -----------------------------------------------
    print("== Systolic array (templates + dangling edges, §4.2) ==")
    for r in (2, 4):
        ag, _ = make_systolic_ag(r, r)
        init_systolic_memory(ag, A, B)
        res = simulate(ag, systolic_gemm_program(8, 8, 8, r, r))
        ok = np.array_equal(read_systolic_result(ag, 8, 8), A @ B)
        print(f"  {r}x{r} PE grid:             {res.cycles:6d} cycles  correct={ok}")

    # --- §4.3 Γ̈ -------------------------------------------------------------
    print("== Γ̈ (fused-tensor level, §4.3) ==")
    Af = A.astype(np.float32); Bf = B.astype(np.float32)
    for nu in (1, 2):
        ag, _ = make_gamma_ag(n_units=nu)
        init_gemm_memory(ag, Af, Bf, memory="dram0", tile=8)
        units = tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(nu))
        res = simulate(ag, gamma_gemm(8, 8, 8, tile=8, units=units,
                                      activation=1))
        C = read_gemm_result(ag, 8, 8, c_base=0x100000, memory="dram0", tile=8)
        ok = np.allclose(C, np.maximum(Af @ Bf, 0))
        print(f"  {nu} compute unit(s), fused ReLU: {res.cycles:5d} cycles  "
              f"correct={ok}")

    # --- §6 AIDG fast path ---------------------------------------------------
    print("== AIDG fast estimation (§6, [16]) ==")
    ag, _ = make_gamma_ag(n_units=2)
    init_gemm_memory(ag, Af, Bf, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(8, 8, 8, tile=8, units=units)
    sim_cycles = simulate(ag, prog).cycles
    est, aidg = estimate_cycles(ag, prog)
    print(f"  event simulator: {sim_cycles} cycles; AIDG estimate: {est:.0f} "
          f"({aidg.n} nodes, {aidg.edges} edges)")


if __name__ == "__main__":
    main()
