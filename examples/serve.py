"""Serving example: prefill a batch of prompts, then decode with KV/SSM
caches — across three model families (GQA, MLA, SSM).

    PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import get_model


def generate(arch: str, prompt_len: int = 16, gen_len: int = 24,
             batch: int = 4):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len), 0,
                                 cfg.vocab_size)
    batch_in = {"tokens": prompts}
    if cfg.n_patches:
        batch_in["patches"] = jnp.zeros((batch, cfg.n_patches, cfg.d_model))
    if cfg.enc_dec is not None:
        batch_in["frames"] = jnp.zeros(
            (batch, cfg.enc_dec.encoder_len, cfg.d_model))

    cache = model.init_cache(batch, prompt_len + gen_len)
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, batch_in, cache)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen_len - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"{arch:22s} prefill {prompt_len} toks: {t_prefill*1e3:7.1f} ms | "
          f"decode {gen_len} toks: {t_decode*1e3/gen_len:6.1f} ms/tok | "
          f"sample: {toks[0, :8].tolist()}")


def main():
    for arch in ("olmo-1b", "minicpm3-4b", "falcon-mamba-7b",
                 "jamba-v0.1-52b", "whisper-small"):
        generate(arch)


if __name__ == "__main__":
    main()
