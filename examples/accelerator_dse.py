"""Accelerator design-space exploration — the paper's motivating use-case
(§1: "selecting an accelerator that aligns with their product's
performance requirements"; §7: NAS / DNN-HW co-design loop).

Sweeps 512 candidate Γ̈-like accelerators (MXU speed, DRAM latency, ...)
against a GeMM workload in ONE batched JAX call over the AIDG, then
reports the Pareto-best few.

    PYTHONPATH=src python examples/accelerator_dse.py
"""

import time

import numpy as np

from repro.core.acadl.sim import build_trace
from repro.core.aidg import build_aidg, make_problem, sweep
from repro.core.archs import make_gamma_ag
from repro.core.mapping.gemm import gamma_gemm, init_gemm_memory


def main():
    # workload: 64x64x64 GeMM on a 2-unit Γ̈
    A = np.ones((64, 64), np.float32)
    ag, _ = make_gamma_ag(n_units=2)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(64, 64, 64, tile=8, units=units)

    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    prob = make_problem(aidg)
    print(f"workload: {aidg.n} instructions, {aidg.edges} AIDG edges")
    print(f"tunable op classes: {prob.op_names}")
    print(f"tunable storages:   {prob.storage_names}")

    # candidate space: multiplicative latency factors over the baseline
    rng = np.random.default_rng(0)
    B = 512
    thetas_op = rng.uniform(0.25, 4.0, (B, prob.n_op)).astype(np.float32)
    thetas_st = rng.uniform(0.25, 4.0, (B, prob.n_st)).astype(np.float32)
    thetas_op[0] = 1.0
    thetas_st[0] = 1.0  # candidate 0 = the baseline machine

    t0 = time.perf_counter()
    cycles = sweep(prob, thetas_op, thetas_st)
    dt = time.perf_counter() - t0
    print(f"\nswept {B} candidate accelerators in {dt:.2f}s "
          f"({B / dt:.0f} designs/s)")
    print(f"baseline: {cycles[0]:.0f} cycles")

    # a crude cost model: faster units cost more silicon
    cost = (1 / thetas_op).sum(axis=1) + (1 / thetas_st).sum(axis=1)
    score = cycles * cost                      # latency-cost product
    best = np.argsort(score)[:5]
    print("\ntop-5 by cycles x cost:")
    for i in best:
        ops = ", ".join(f"{n}x{thetas_op[i, j]:.2f}"
                        for j, n in enumerate(prob.op_names))
        sts = ", ".join(f"{n}x{thetas_st[i, j]:.2f}"
                        for j, n in enumerate(prob.storage_names))
        print(f"  #{i:3d}: {cycles[i]:7.0f} cycles  cost {cost[i]:5.2f}  "
              f"[{ops} | {sts}]")


if __name__ == "__main__":
    main()
