"""Multi-architecture accelerator design-space exploration — the paper's
motivating use-case (§1: "selecting an accelerator that aligns with their
product's performance requirements"; §7: NAS / DNN-HW co-design loop).

Sweeps a shared 5-knob design space (matrix unit, vector unit, load/store,
on-chip SRAM, DRAM — multiplicative latency factors) over the FULL scenario
matrix: 6 modeled architectures x their mapped workloads (GEMM, conv,
attention, selective-scan, map-reduce), >= 1000 candidates per batch, one
batched JAX sweep per cached AIDG.  Reports the Pareto frontier of
(latency, energy, cost/area proxy) and two refinements of the incumbent: classic
derivative-free coordinate descent, and gradient descent through the
smooth max-plus relaxation (the sweep is pure JAX, so the makespan is
differentiable in the design knobs — batched multi-start projected Adam
needs half the candidate evaluations).  Finishes with whole-network cells
(``repro.core.network``): entire DNNs lowered layer-by-layer and
co-optimized against end-to-end latency, including the sequential vs
double-buffer-pipelined composition.

    PYTHONPATH=src python examples/accelerator_dse.py
"""

import time

import numpy as np

from repro.core.aidg.explorer import (Explorer, grid_candidates,
                                      random_candidates)
from repro.core.aidg.gradient import GradientExplorer


def main():
    t0 = time.perf_counter()
    ex = Explorer()
    names = ex.scenario_names
    print(f"scenario matrix ({len(names)} cells, "
          f"compiled in {time.perf_counter() - t0:.2f}s):")
    for cs in ex.compiled:
        s = cs.schedule
        print(f"  {cs.name:20s} {cs.aidg.n:5d} instructions, "
              f"{s.n_levels:5d} wavefront levels "
              f"({s.parallelism:4.1f}x parallel), "
              f"baseline {cs.baseline:8.0f} cycles")

    # --- candidates: full factorial grid + log-uniform random ------------
    cand = np.concatenate([
        grid_candidates(ex.space, points=3),          # 3^5 = 243
        random_candidates(ex.space, 1024, seed=0),    # its row 0 (index 243
    ])                                                #  here) = baseline θ=1
    print(f"\nknobs: {ex.space.names}")
    print(f"candidates: {cand.shape[0]} "
          f"(x {len(names)} scenarios = {cand.shape[0] * len(names)} cells)")

    ex.explore(cand)  # warm-up: JIT-compile each scenario at this batch shape
    t0 = time.perf_counter()
    res = ex.explore(cand)
    dt = time.perf_counter() - t0
    thr = cand.shape[0] * len(names) / dt
    print(f"swept in {dt:.2f}s ({thr:.0f} (arch, workload, theta) configs/s, "
          "steady-state)")

    # --- Pareto frontier of (latency, energy, cost) -----------------------
    print(f"\nPareto frontier ({len(res.pareto)} non-dominated designs, "
          "latency = mean baseline-relative cycles, energy = mean "
          "baseline-relative energy, cost = area proxy):")
    frontier = res.frontier()
    step = max(1, len(frontier) // 8)
    for row in frontier[::step]:
        thetas = ", ".join(f"{n}x{row[f'theta[{n}]']:.2f}"
                           for n in ex.space.names)
        print(f"  latency {row['latency']:.3f}  "
              f"energy {row['energy']:.3f}  cost {row['cost']:6.2f}  "
              f"[{thetas}]")

    i = res.best
    print(f"\nbest latency*cost compromise (candidate {i}): "
          f"latency {res.latency[i]:.3f}, energy {res.energy[i]:.3f}, "
          f"cost {res.cost[i]:.2f}")
    per_scn = ", ".join(f"{n}={c:.0f}" for n, c in zip(names, res.cycles[i]))
    print(f"  cycles: {per_scn}")

    # --- coordinate-descent refinement ------------------------------------
    t0 = time.perf_counter()
    best = ex.refine(rounds=2, points=7)
    ref = ex.explore(best[None, :])
    cd_evals = (7 + 1) * ex.space.n * 2
    print(f"\ncoordinate descent ({time.perf_counter() - t0:.2f}s, "
          f"{cd_evals} candidates) -> latency {ref.latency[0]:.3f}, "
          f"cost {ref.cost[0]:.2f}, "
          f"product {ref.latency[0] * ref.cost[0]:.3f}")
    print("  theta:", {n: round(float(v), 3)
                       for n, v in zip(ex.space.names, best)})

    # --- gradient refinement over the smooth max-plus relaxation ----------
    # batched multi-start projected Adam in log-knob space, τ annealed from
    # a heavily smoothed landscape to a near-exact one; the final score is
    # re-judged by the hard evaluator (same objective as everything above)
    t0 = time.perf_counter()
    res = GradientExplorer(ex).refine()
    gref = ex.explore(res.theta[None, :])
    print(f"gradient descent ({time.perf_counter() - t0:.2f}s, "
          f"{res.evaluations} candidates) -> "
          f"latency {gref.latency[0]:.3f}, cost {gref.cost[0]:.2f}, "
          f"product {res.score:.3f}")
    print("  theta:", {n: round(float(v), 3)
                       for n, v in zip(ex.space.names, res.theta)})

    # --- whole networks as cells: the paper's actual artifact -------------
    # lower entire DNNs (layer graph -> per-layer AIDG -> max-plus
    # composition) onto a couple of architectures and co-optimize the SAME
    # shared knobs against end-to-end network latency
    from repro.core.network import NetworkScenario, default_network_scenarios

    t0 = time.perf_counter()
    nex = Explorer(scenarios=default_network_scenarios(
        networks=["whisper_small", "olmo_1b"], archs=["gamma", "tpu_v5e"]))
    print(f"\nnetwork matrix ({len(nex.scenario_names)} cells, compiled in "
          f"{time.perf_counter() - t0:.2f}s):")
    for i, cn in enumerate(nex.compiled):
        print(f"  {cn.name:24s} {len(cn.layer_graph.instances):4d} layer "
              f"instances -> {cn.n_layers} unique programs, "
              f"baseline {float(nex.baselines[i]):.3e} cycles end-to-end")
    theta = nex.refine(method="grad", starts=2, steps=10)
    nref = nex.explore(theta[None, :])
    print(f"  gradient co-design on end-to-end latency -> "
          f"latency {nref.latency[0]:.3f}, cost {nref.cost[0]:.2f}")

    # sequential vs double-buffer-pipelined composition of one cell
    seq = NetworkScenario("tpu_v5e", "olmo_1b").compile()
    pip = NetworkScenario("tpu_v5e", "olmo_1b", mode="pipelined").compile()
    one = np.ones((1, nex.space.n), np.float32)
    s = float(seq.evaluate(nex.space, one)[0])
    p = float(pip.evaluate(nex.space, one)[0])
    print(f"  olmo-1b on tpu_v5e: sequential {s:.3e} cycles, "
          f"pipelined {p:.3e} ({100 * (1 - p / s):.0f}% hidden by "
          f"double-buffered overlap)")

    # --- energy as a co-equal objective -----------------------------------
    # every architecture carries per-op-class pJ coefficients (per-tech-node
    # tables); the packed dispatch returns (cycles, energy) together, so
    # energy-targeted co-design reuses the same compiled kernel
    from repro.core.aidg.energy import energy_bottleneck_report
    from repro.core.archs.energy import energy_model

    eres = GradientExplorer(nex, objective="edp").refine(starts=2, steps=10)
    eref = nex.explore(eres.theta[None, :])
    print(f"\nenergy-delay co-design on the network matrix -> "
          f"latency {eref.latency[0]:.3f}, energy {eref.energy[0]:.3f}, "
          f"cost {eref.cost[0]:.2f}")
    print("  theta:", {n: round(float(v), 3)
                       for n, v in zip(nex.space.names, eres.theta)})

    # where do the joules go?  storage-node traffic x per-level access
    # energy, grouped by storage class (the ZigZag-style breakdown)
    em = energy_model("tpu_v5e")
    print(f"  memory-level energy bottlenecks, olmo-1b on tpu_v5e "
          f"({em.tech_nm} nm tables):")
    for row in energy_bottleneck_report(seq):
        print(f"    {row['storage_class']:7s} {row['words']:.3e} words "
              f"x {row['pj_per_word']:7.1f} pJ = "
              f"{row['energy_pj']:.3e} pJ ({100 * row['share']:.0f}%)")


if __name__ == "__main__":
    main()
