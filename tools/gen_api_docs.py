#!/usr/bin/env python
"""Generate ``docs/api/`` from docstrings (and keep it honest in CI).

Covers the AIDG engine and the network frontend — the modules whose public
surfaces the DSE documentation links into.  One markdown file per module,
deterministic output, so the generated tree can be committed and
drift-checked:

    PYTHONPATH=src python tools/gen_api_docs.py           # (re)generate
    PYTHONPATH=src python tools/gen_api_docs.py --check   # CI: fail on drift

The generator also enforces the docstring audit: any public symbol (module,
``__all__`` entry, or public method/property of an exported class) without
a docstring is an error, so new engine code can't land undocumented.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import pathlib
import sys
from typing import Dict, List

ROOT = pathlib.Path(__file__).resolve().parent.parent
API_DIR = ROOT / "docs" / "api"

MODULES = [
    "repro.core.aidg.builder",
    "repro.core.aidg.maxplus",
    "repro.core.aidg.dse",
    "repro.core.aidg.explorer",
    "repro.core.aidg.gradient",
    "repro.core.aidg.energy",
    "repro.core.archs.energy",
    "repro.core.network.graph",
    "repro.core.network.lowering",
    "repro.core.network.model",
    "repro.serve.query",
    "repro.serve.batcher",
    "repro.serve.engine",
    "repro.serve.errors",
    "repro.serve.policy",
    "repro.serve.faults",
    "repro.serve.frontend",
    "repro.surrogate.model",
    "repro.surrogate.train",
]


import re

_ADDR_RE = re.compile(r"<(?:function|class|built-in \w+) ([\w.<>]+) at "
                      r"0x[0-9a-f]+>")


def _signature(obj) -> str:
    try:
        sig = str(inspect.signature(obj))
    except (ValueError, TypeError):
        return "(...)"
    # default values that repr with a memory address (e.g. function
    # defaults) would make the output nondeterministic — keep the name
    return _ADDR_RE.sub(r"\1", sig)


def _doc(obj, owner: str, errors: List[str]) -> str:
    doc = inspect.getdoc(obj)
    if not doc:
        errors.append(f"missing docstring: {owner}")
        return "*(undocumented)*"
    return doc


def _class_section(name: str, cls: type, errors: List[str]) -> List[str]:
    lines = [f"## `{name}{_signature(cls)}`", "",
             _doc(cls, name, errors), ""]
    members = []
    for mname, m in vars(cls).items():
        if mname.startswith("_"):
            continue
        if isinstance(m, property):
            members.append((mname, f"`{name}.{mname}` *(property)*",
                            m.fget))
        elif inspect.isfunction(m):
            members.append((mname, f"`{name}.{mname}{_signature(m)}`", m))
    for mname, head, fn in members:
        doc = _doc(fn, f"{name}.{mname}", errors)
        lines += [f"### {head}", "", doc, ""]
    return lines


def render_module(dotted: str, errors: List[str]) -> str:
    mod = importlib.import_module(dotted)
    lines = [f"# `{dotted}`", "",
             _doc(mod, dotted, errors), ""]
    exported = list(getattr(mod, "__all__", []))
    for name in exported:
        obj = getattr(mod, name)
        if getattr(obj, "__module__", dotted) != dotted:
            continue                      # re-export; documented at home
        if inspect.isclass(obj):
            lines += _class_section(name, obj, errors)
        elif inspect.isfunction(obj):
            lines += [f"## `{name}{_signature(obj)}`", "",
                      _doc(obj, f"{dotted}.{name}", errors), ""]
        else:
            lines += [f"## `{name}`", "", f"Constant: `{obj!r}`", ""]
    return "\n".join(lines).rstrip() + "\n"


def render_index() -> str:
    lines = ["# API reference", "",
             "Generated from docstrings by `tools/gen_api_docs.py` "
             "(drift-checked in CI — regenerate after changing any public "
             "docstring):", ""]
    for dotted in MODULES:
        lines.append(f"* [`{dotted}`]({dotted}.md)")
    return "\n".join(lines) + "\n"


def build() -> Dict[str, str]:
    """filename -> rendered content; raises on undocumented public API."""
    errors: List[str] = []
    out = {f"{dotted}.md": render_module(dotted, errors)
           for dotted in MODULES}
    out["index.md"] = render_index()
    if errors:
        for e in errors:
            print(f"ERROR: {e}", file=sys.stderr)
        raise SystemExit(1)
    return out


def diff_against_disk(rendered: Dict[str, str]) -> List[str]:
    """Error strings for every stale/extra page under docs/api/ — the one
    comparison shared by ``--check`` here and ``tools/check_docs.py``."""
    errors = [f"docs/api/{fn} is stale — rerun tools/gen_api_docs.py"
              for fn, content in rendered.items()
              if not (API_DIR / fn).exists()
              or (API_DIR / fn).read_text() != content]
    errors += [f"docs/api/{p.name} has no generating module — delete it or "
               f"add the module to gen_api_docs.MODULES"
               for p in sorted(API_DIR.glob("*.md"))
               if p.name not in rendered]
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if docs/api/ differs from the generated "
                         "output instead of writing it")
    args = ap.parse_args()
    sys.path.insert(0, str(ROOT / "src"))
    rendered = build()
    if args.check:
        errors = diff_against_disk(rendered)
        for e in errors:
            print(f"ERROR: {e}", file=sys.stderr)
        print(f"checked {len(rendered)} generated API pages")
        return 1 if errors else 0
    API_DIR.mkdir(parents=True, exist_ok=True)
    for fn, content in rendered.items():
        (API_DIR / fn).write_text(content)
    print(f"wrote {len(rendered)} pages to {API_DIR}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
