#!/usr/bin/env python
"""Train, calibrate, and evaluate the surrogate oracle tier — reproducibly.

One fixed seed drives the whole pipeline (sweep sampling, parameter init,
held-out split), so running this twice writes byte-identical artifacts:

    PYTHONPATH=src python tools/train_surrogate.py --out artifacts/surrogate

writes ``surrogate.npz`` (the deployable :class:`SurrogateBundle`) and
``eval.json`` (fresh-sample error report), and prints the per-cell
calibration table that ``docs/surrogate.md`` quotes.

``--smoke`` is the CI mode: train a 1-cell surrogate (the first operator
scenario) from the fixed seed and assert its fresh-sample error stays
inside the stated confidence bound — a fast end-to-end regression of the
train → calibrate → predict loop.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core.aidg.explorer import (Explorer, default_scenarios)  # noqa: E402
from repro.surrogate import (SurrogateConfig, evaluate_surrogate,  # noqa: E402
                             train_surrogate)


def build_explorer(args) -> Explorer:
    """The training oracle: the full 31-cell matrix by default, the first
    operator cell in ``--smoke`` mode, or a name-filtered subset."""
    if args.smoke:
        return Explorer(scenarios=default_scenarios()[:1])
    if args.cells:
        keep = [s for s in default_scenarios()
                if any(pat in s.name for pat in args.cells)]
        if not keep:
            raise SystemExit(f"--cells {args.cells} matched no scenario")
        return Explorer(scenarios=keep)
    return Explorer(networks=True)


def main(argv=None) -> int:
    """CLI entry point; returns a process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0,
                    help="drives sampling, init, and the held-out split")
    ap.add_argument("--samples", type=int, default=192,
                    help="log-uniform sweep draws (row 0 is always θ=1)")
    ap.add_argument("--steps", type=int, default=1500,
                    help="AdamW steps (cosine-decayed lr)")
    ap.add_argument("--out", type=Path, default=Path("artifacts/surrogate"),
                    help="artifact directory (surrogate.npz + eval.json)")
    ap.add_argument("--cells", nargs="*", default=None, metavar="SUBSTR",
                    help="train only operator cells whose name contains "
                         "any of these substrings (default: full matrix)")
    ap.add_argument("--eval-n", type=int, default=48,
                    help="fresh evaluation draws the training never saw")
    ap.add_argument("--max-err", type=float, default=0.02,
                    help="smoke mode: required median latency error bound")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 1-cell train + error-bound assertion")
    args = ap.parse_args(argv)

    ex = build_explorer(args)
    cfg = SurrogateConfig(seed=args.seed, n_samples=args.samples,
                          steps=args.steps)
    print(f"training surrogate: {len(ex.compiled)} cells, "
          f"{cfg.n_samples} samples, {cfg.steps} steps, seed {cfg.seed}")
    bundle = train_surrogate(ex, cfg)
    report = evaluate_surrogate(bundle, ex, n=args.eval_n,
                                seed=args.seed + 1234)

    med_lat = np.median(report["err_latency"], axis=0)
    med_en = np.median(report["err_energy"], axis=0)
    print(f"{'cell':<34} {'bound':>7} {'med lat':>8} {'med en':>8} "
          f"{'cover':>6}")
    for i, name in enumerate(bundle.cell_names):
        print(f"{name:<34} {bundle.err_bound[i]:>7.4f} {med_lat[i]:>8.4f} "
              f"{med_en[i]:>8.4f} {report['bound_coverage'][i]:>6.2f}")
    print(f"matrix-wide median latency err "
          f"{report['median_latency_err']:.4f}, "
          f"energy err {report['median_energy_err']:.4f}")

    args.out.mkdir(parents=True, exist_ok=True)
    bundle.save(args.out / "surrogate.npz")
    summary = {
        "cells": report["cells"],
        "err_bound": bundle.err_bound.tolist(),
        "median_latency_err": report["median_latency_err"],
        "median_energy_err": report["median_energy_err"],
        "median_latency_err_per_cell": med_lat.tolist(),
        "median_energy_err_per_cell": med_en.tolist(),
        "bound_coverage": np.asarray(report["bound_coverage"]).tolist(),
        "config": bundle.meta.get("config", {}),
    }
    (args.out / "eval.json").write_text(json.dumps(summary, indent=2))
    print(f"wrote {args.out / 'surrogate.npz'} and {args.out / 'eval.json'}")

    if args.smoke:
        ok = report["median_latency_err"] <= args.max_err
        print(f"smoke: median latency err {report['median_latency_err']:.4f}"
              f" {'<=' if ok else '>'} required {args.max_err}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
