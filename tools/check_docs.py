#!/usr/bin/env python
"""Docs lint: every ```python block in README.md and docs/*.md must parse,
and every import statement in those blocks must actually resolve against
the installed package — so the documentation can't silently drift from the
API.  Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def doc_files():
    yield ROOT / "README.md"
    yield from sorted((ROOT / "docs").glob("*.md"))


def check_block(path: pathlib.Path, idx: int, code: str) -> list[str]:
    errors = []
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        return [f"{path.name} block {idx}: does not parse: {e}"]
    # run just the imports: the cheap end-to-end check that every
    # documented symbol exists
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            stmt = ast.unparse(node)
            try:
                exec(compile(ast.Module([node], []), "<doc>", "exec"), {})
            except Exception as e:
                errors.append(f"{path.name} block {idx}: {stmt!r} -> "
                              f"{type(e).__name__}: {e}")
    return errors


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    errors, blocks = [], 0
    for path in doc_files():
        if not path.exists():
            errors.append(f"missing documentation file: {path.name}")
            continue
        for idx, m in enumerate(BLOCK_RE.finditer(path.read_text())):
            blocks += 1
            errors.extend(check_block(path, idx, m.group(1)))
    print(f"checked {blocks} python blocks in "
          f"{len(list(doc_files()))} documentation files")
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
