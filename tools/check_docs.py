#!/usr/bin/env python
"""Docs lint — keeps the documentation from drifting off the code.

Four checks over README.md, docs/*.md, and docs/api/*.md:

1. every ```python block parses, and every import statement in it
   resolves against the installed package;
2. every relative markdown link points at a file that exists, and every
   ``#anchor`` (same-file or cross-file) matches a real heading;
3. every backticked ``repro.…`` dotted path in docs/paper_map.md resolves
   via import + getattr — the paper cross-reference table can't go stale;
4. ``docs/api/`` matches what ``tools/gen_api_docs.py`` would generate
   (drift check, which also enforces the public-docstring audit).

Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import ast
import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
# [text](target) — skip images and external/absolute targets
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
MODPATH_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def doc_files():
    yield ROOT / "README.md"
    yield from sorted((ROOT / "docs").glob("*.md"))
    yield from sorted((ROOT / "docs" / "api").glob("*.md"))


def check_block(path: pathlib.Path, idx: int, code: str) -> list[str]:
    errors = []
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        return [f"{path.name} block {idx}: does not parse: {e}"]
    # run just the imports: the cheap end-to-end check that every
    # documented symbol exists
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            stmt = ast.unparse(node)
            try:
                exec(compile(ast.Module([node], []), "<doc>", "exec"), {})
            except Exception as e:
                errors.append(f"{path.name} block {idx}: {stmt!r} -> "
                              f"{type(e).__name__}: {e}")
    return errors


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash
    spaces (inline code markers stripped first)."""
    h = heading.replace("`", "").strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: pathlib.Path) -> set[str]:
    text = FENCE_RE.sub("", path.read_text())
    return {_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_links(path: pathlib.Path) -> list[str]:
    """Relative links resolve; anchors match headings in their target."""
    errors = []
    text = FENCE_RE.sub("", path.read_text())
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        rel, _, anchor = target.partition("#")
        dest = path if not rel else (path.parent / rel).resolve()
        if not dest.exists():
            errors.append(f"{path.name}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and anchor not in _anchors(dest):
            errors.append(f"{path.name}: broken anchor -> {target}")
    return errors


def check_module_paths(path: pathlib.Path) -> list[str]:
    """Backticked repro.* dotted paths import (trailing attribute OK)."""
    errors = []
    for m in MODPATH_RE.finditer(path.read_text()):
        dotted = m.group(1)
        parts = dotted.split(".")
        obj = None
        for split in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:split]))
                break
            except ImportError:
                continue
        if obj is None:
            errors.append(f"{path.name}: stale module path `{dotted}`")
            continue
        try:
            for attr in parts[split:]:
                obj = getattr(obj, attr)
        except AttributeError:
            errors.append(f"{path.name}: stale module path `{dotted}` "
                          f"(no attribute {attr!r})")
    return errors


def check_api_drift() -> list[str]:
    """docs/api/ must match the generator's output byte-for-byte (the
    comparison itself lives in gen_api_docs.diff_against_disk)."""
    sys.path.insert(0, str(ROOT / "tools"))
    import gen_api_docs
    try:
        rendered = gen_api_docs.build()
    except SystemExit:
        return ["gen_api_docs: public API has undocumented symbols "
                "(see errors above)"]
    return gen_api_docs.diff_against_disk(rendered)


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    errors, blocks, links = [], 0, 0
    for path in doc_files():
        if not path.exists():
            errors.append(f"missing documentation file: {path.name}")
            continue
        for idx, m in enumerate(BLOCK_RE.finditer(path.read_text())):
            blocks += 1
            errors.extend(check_block(path, idx, m.group(1)))
        links += len(LINK_RE.findall(FENCE_RE.sub("", path.read_text())))
        errors.extend(check_links(path))
    paper_map = ROOT / "docs" / "paper_map.md"
    if paper_map.exists():
        errors.extend(check_module_paths(paper_map))
    else:
        errors.append("missing documentation file: paper_map.md")
    errors.extend(check_api_drift())
    print(f"checked {blocks} python blocks and {links} links in "
          f"{len(list(doc_files()))} documentation files "
          f"(+ paper-map paths, + docs/api drift)")
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
