"""Recorded-baseline regression guard for the bench trajectory.

``benchmarks/run.py --json`` records each section's rows as
``BENCH_<section>.json`` (full budget) / ``BENCH_<section>_<budget>.json``
(other budgets).  This module ratio-compares a LIVE bench run against the
checked-in snapshot so serving-path slowdowns fail CI loudly instead of
drifting: for every guarded row, the live throughput metric must be at
least ``tolerance`` x the recorded one (default 0.5 — an injected 2x
slowdown breaches).

Budget matching: throughput at different budgets is structurally
different (a 64-candidate smoke batch amortizes dispatch overhead far
less than the 1024-candidate full run — measured ~0.47x on ``dse/packed``),
so the guard prefers the budget-matched snapshot and, when only the
full-budget snapshot exists, scales the tolerance by
``CROSS_BUDGET_FACTOR`` so the comparison stays meaningful without going
blind.

Environment knobs (CI wiring):

* ``BENCH_BASELINE_TOL``   — override the tolerance (default 0.5).
* ``BENCH_BASELINE_GUARD`` — ``1`` forces the guard on any budget,
  ``0`` disables it (default: enabled exactly for the small-budget smoke
  run, the CI tier; full-budget runs RECORD baselines rather than check
  them).

The comparator itself is unit-tested on synthetic snapshots (missing
row, within-tolerance, breach) in ``tests/test_bench_guard.py`` — the
guard is verified, not just wired.
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
from typing import Dict, List, Optional, Sequence

from .run import parse_derived

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# extra tolerance headroom when only a differently-budgeted snapshot is
# available (see module docstring for the measured cross-budget ratio)
CROSS_BUDGET_FACTOR = 0.5

# the serving-path rows bench-smoke guards, and the throughput metric
GUARDED_ROWS = ("dse/packed", "dse/energy", "network/matrix")
GUARD_METRIC = "configs_per_s"


def snapshot_path(section: str, budget: str = "full",
                  out_dir: Optional[str] = None) -> pathlib.Path:
    """Snapshot file for (section, budget): ``BENCH_<section>.json`` for
    the full budget, ``BENCH_<section>_<budget>.json`` otherwise."""
    base = pathlib.Path(out_dir) if out_dir else REPO_ROOT
    suffix = "" if budget in ("full", "", None) else f"_{budget}"
    return base / f"BENCH_{section}{suffix}.json"


def load_baseline(section: str, budget: str = "full",
                  out_dir: Optional[str] = None) -> Optional[Dict]:
    """The recorded snapshot for (section, budget), preferring the
    budget-matched file and falling back to the full-budget one;
    ``None`` when neither exists."""
    for b in (budget, "full"):
        path = snapshot_path(section, b, out_dir)
        if path.exists():
            with open(path) as fh:
                return json.load(fh)
    return None


def check_rows(live_rows: Sequence[Dict], baseline: Dict,
               names: Sequence[str] = GUARDED_ROWS,
               metric: str = GUARD_METRIC,
               tolerance: float = 0.5) -> List[str]:
    """Ratio-compare live rows against a snapshot; returns the list of
    problems (empty = guard passes).

    For each guarded ``name``: the row must exist on BOTH sides, carry a
    numeric ``metric``, and satisfy ``live >= tolerance * recorded``.
    ``live_rows`` are bench-harness rows (``derived`` key=value strings,
    parsed here); snapshot rows carry pre-parsed ``metrics``."""
    problems: List[str] = []
    base_by_name = {r["name"]: r for r in baseline.get("rows", [])}
    live_by_name = {r["name"]: r for r in live_rows}
    for name in names:
        live = live_by_name.get(name)
        if live is None:
            problems.append(f"{name}: missing from the live run")
            continue
        base = base_by_name.get(name)
        if base is None:
            problems.append(f"{name}: missing from the recorded snapshot")
            continue
        lv = parse_derived(live.get("derived", "")).get(metric)
        bv = base.get("metrics", {}).get(metric)
        if not isinstance(lv, float):
            problems.append(f"{name}: live run has no numeric {metric!r}")
            continue
        if not isinstance(bv, float) or bv <= 0:
            problems.append(f"{name}: snapshot has no numeric {metric!r}")
            continue
        if lv < tolerance * bv:
            problems.append(
                f"{name}: {metric} regressed to {lv:.0f} "
                f"({lv / bv:.2f}x the recorded {bv:.0f}; "
                f"floor = {tolerance:.2f}x)")
    return problems


def assert_baseline(live_rows: Sequence[Dict], section: str = "dse",
                    names: Sequence[str] = GUARDED_ROWS,
                    metric: str = GUARD_METRIC,
                    tolerance: Optional[float] = None,
                    budget: Optional[str] = None,
                    out_dir: Optional[str] = None) -> None:
    """The CI wiring: load the recorded snapshot for this budget and
    raise ``AssertionError`` on any breach.  Tolerance resolution:
    explicit argument > ``BENCH_BASELINE_TOL`` env > 0.5; scaled by
    ``CROSS_BUDGET_FACTOR`` when falling back across budgets.  A missing
    snapshot is itself an error — a deleted baseline must not silently
    disarm the guard."""
    if budget is None:
        budget = os.environ.get("BENCH_BUDGET", "full") or "full"
    if tolerance is None:
        tolerance = float(os.environ.get("BENCH_BASELINE_TOL", "0.5"))
    baseline = load_baseline(section, budget, out_dir)
    if baseline is None:
        raise AssertionError(
            f"no recorded baseline for section {section!r} "
            f"(expected {snapshot_path(section, budget, out_dir).name} or "
            f"{snapshot_path(section, 'full', out_dir).name}; record one "
            f"with `python -m benchmarks.run --json`)")
    if baseline.get("budget", "full") != budget:
        tolerance *= CROSS_BUDGET_FACTOR
        print(f"# baseline guard: comparing {budget!r} run against "
              f"{baseline.get('budget', 'full')!r} snapshot, tolerance "
              f"scaled to {tolerance:.2f}x", file=sys.stderr)
    problems = check_rows(live_rows, baseline, names, metric, tolerance)
    if problems:
        raise AssertionError(
            "recorded-baseline guard failed:\n  " + "\n  ".join(problems))


def guard_enabled(budget: Optional[str] = None) -> bool:
    """Whether the guard should run: forced by ``BENCH_BASELINE_GUARD``
    (1/0), otherwise exactly on the small-budget smoke tier."""
    env = os.environ.get("BENCH_BASELINE_GUARD")
    if env is not None:
        return env not in ("0", "false", "")
    if budget is None:
        budget = os.environ.get("BENCH_BUDGET", "full") or "full"
    return budget == "small"
