"""Workload mapping benchmark (§5): every assigned architecture's train_4k
step mapped onto the TPU-v5e ACADL model; AIDG step-time estimate vs the
analytic compute roofline (cross-validation of the accelerator model)."""

from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import all_arch_ids, get_config
from repro.core.aidg import estimate_cycles
from repro.core.archs import TPU_V5E, make_tpu_v5e_ag
from repro.core.mapping.workload import map_to_tpu
from repro.models import SHAPES


def run(rows: List[Dict]) -> None:
    shape = SHAPES["train_4k"]
    chips = 256
    for arch in all_arch_ids():
        cfg = get_config(arch)
        ag, _ = make_tpu_v5e_ag()
        prog = map_to_tpu(cfg, shape, per_device=chips)
        t0 = time.perf_counter()
        cycles, aidg = estimate_cycles(ag, prog)
        dt = time.perf_counter() - t0
        secs = cycles / (TPU_V5E["clock_ghz"] * 1e9)
        tokens = shape.global_batch * shape.seq_len
        analytic = (6 * cfg.n_active_params() * tokens / chips
                    / TPU_V5E["peak_bf16_flops"])
        rows.append({"name": f"workload/{arch}", "us_per_call": dt * 1e6,
                     "derived": (f"est_ms_per_step={secs * 1e3:.1f};"
                                 f"analytic_ms={analytic * 1e3:.1f};"
                                 f"ratio={secs / max(analytic, 1e-12):.2f};"
                                 f"instrs={len(prog)}")})
