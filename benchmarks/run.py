"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (and writes bench_output.txt is the
caller's job via tee).  Usage: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
from typing import Dict, List


def main() -> int:
    from . import bench_dse, bench_kernels, bench_paper, bench_workloads

    rows: List[Dict] = []
    for mod in (bench_paper, bench_dse, bench_workloads, bench_kernels):
        mod.run(rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
