"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (and writes bench_output.txt is the
caller's job via tee).

Usage:  PYTHONPATH=src python -m benchmarks.run [--only SECTION[,SECTION]]
                                                [--json [DIR]]

``--only dse`` runs just the DSE sections (what the CI smoke step uses,
together with ``BENCH_BUDGET=small``); sections: paper, dse, workloads,
kernels, serve.

``--json [DIR]`` additionally persists each section's rows as
``BENCH_<section>.json`` (default DIR: the repository root) with the
``derived`` key=value pairs parsed out, so future sessions can assert
against a *recorded* trajectory instead of re-measuring ad hoc — e.g.
``BENCH_dse.json["rows"][i]["metrics"]["configs_per_s"]``.  Non-full
budgets write ``BENCH_<section>_<budget>.json`` (e.g.
``BENCH_dse_small.json``) so the recorded-baseline guard
(``benchmarks/baseline.py``) always compares like-for-like budgets.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
from typing import Dict, List

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def parse_derived(derived: str) -> Dict[str, object]:
    """``k=v;k=v`` -> dict, values parsed as float where they look like
    one (a trailing unit such as ``x`` or a ``a->b`` arrow keeps the raw
    string — the reader decides how to interpret those)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def write_json(section: str, rows: List[Dict], out_dir: str) -> str:
    """Persist one section's rows (with parsed metrics) as
    ``BENCH_<section>.json`` (full budget) or
    ``BENCH_<section>_<budget>.json`` under ``out_dir``; returns the
    path.  The budget suffix keeps smoke-tier snapshots separate from
    the full-budget trajectory — cross-budget throughput is not
    comparable (see ``benchmarks.baseline``)."""
    budget = os.environ.get("BENCH_BUDGET", "full") or "full"
    payload = {
        "section": section,
        "budget": budget,
        "rows": [{"name": r["name"],
                  "us_per_call": round(float(r["us_per_call"]), 3),
                  "derived": r["derived"],
                  "metrics": parse_derived(r["derived"])} for r in rows],
    }
    suffix = "" if budget == "full" else f"_{budget}"
    path = os.path.join(out_dir, f"BENCH_{section}{suffix}.json")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main(argv: List[str] = None) -> int:
    from . import (bench_dse, bench_kernels, bench_paper, bench_serve,
                   bench_workloads)

    sections = {"paper": bench_paper, "dse": bench_dse,
                "workloads": bench_workloads, "kernels": bench_kernels,
                "serve": bench_serve}
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections: "
                         + ",".join(sections))
    ap.add_argument("--json", nargs="?", const=str(REPO_ROOT), default=None,
                    metavar="DIR",
                    help="also write BENCH_<section>.json per section "
                         "(default DIR: repository root)")
    args = ap.parse_args(argv)
    if args.only:
        unknown = set(args.only.split(",")) - set(sections)
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}")
        names = args.only.split(",")
    else:
        names = list(sections)

    all_rows: List[Dict] = []
    for name in names:
        rows: List[Dict] = []
        sections[name].run(rows)
        if args.json is not None:
            path = write_json(name, rows, args.json)
            print(f"# wrote {path}", file=sys.stderr)
        all_rows.extend(rows)

    print("name,us_per_call,derived")
    for r in all_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
