"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (and writes bench_output.txt is the
caller's job via tee).

Usage:  PYTHONPATH=src python -m benchmarks.run [--only SECTION[,SECTION]]

``--only dse`` runs just the DSE sections (what the CI smoke step uses,
together with ``BENCH_BUDGET=small``); sections: paper, dse, workloads,
kernels.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List


def main(argv: List[str] = None) -> int:
    from . import bench_dse, bench_kernels, bench_paper, bench_workloads

    sections = {"paper": bench_paper, "dse": bench_dse,
                "workloads": bench_workloads, "kernels": bench_kernels}
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections: "
                         + ",".join(sections))
    args = ap.parse_args(argv)
    if args.only:
        unknown = set(args.only.split(",")) - set(sections)
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}")
        mods = [sections[s] for s in args.only.split(",")]
    else:
        mods = list(sections.values())

    rows: List[Dict] = []
    for mod in mods:
        mod.run(rows)

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
