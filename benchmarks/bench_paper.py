"""Benchmarks anchored to the paper's worked examples.

* OMA GeMM (§4.1 + §5, Listing 5): looped vs unrolled vs tiled cycles.
* Systolic array (§4.2, Fig. 4): rows x cols scaling.
* Γ̈ (§4.3, Listing 4): compute-unit scaling + fused ReLU.
* AIDG (§6, [16]): accuracy + speedup vs the event-driven oracle.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.acadl import simulate
from repro.core.acadl.sim import build_trace
from repro.core.aidg import build_aidg, estimate_cycles, longest_path_fixed_point
from repro.core.archs import make_gamma_ag, make_oma_ag, make_systolic_ag
from repro.core.mapping.gemm import (gamma_gemm, init_gemm_memory,
                                     oma_gemm_looped, oma_gemm_unrolled)
from repro.core.mapping.systolic import (init_systolic_memory,
                                         systolic_gemm_program)


def bench_oma_gemm(rows: List[Dict]) -> None:
    m = n = l = 8
    A = np.ones((m, n)); B = np.ones((n, l))
    variants = {
        "looped(Listing5)": lambda: oma_gemm_looped(m, n, l),
        "unrolled": lambda: oma_gemm_unrolled(m, n, l),
        "tiled4": lambda: oma_gemm_unrolled(m, n, l, 4, 4, 4),
    }
    for name, make in variants.items():
        ag, _ = make_oma_ag()
        init_gemm_memory(ag, A, B)
        prog = make()
        t0 = time.perf_counter()
        res = simulate(ag, prog)
        dt = time.perf_counter() - t0
        rows.append({"name": f"oma_gemm/{name}", "us_per_call": dt * 1e6,
                     "derived": f"cycles={res.cycles};instrs={res.n_instructions}"})


def bench_systolic(rows: List[Dict]) -> None:
    A = np.ones((8, 16)); B = np.ones((16, 8))
    for r in (2, 4, 8):
        ag, _ = make_systolic_ag(r, r)
        init_systolic_memory(ag, A, B)
        prog = systolic_gemm_program(8, 16, 8, r, r)
        t0 = time.perf_counter()
        res = simulate(ag, prog)
        dt = time.perf_counter() - t0
        rows.append({"name": f"systolic/{r}x{r}", "us_per_call": dt * 1e6,
                     "derived": f"cycles={res.cycles}"})


def bench_gamma(rows: List[Dict]) -> None:
    A = np.ones((32, 32), np.float32)
    for nu in (1, 2, 4):
        ag, _ = make_gamma_ag(n_units=nu)
        init_gemm_memory(ag, A, A, memory="dram0", tile=8)
        units = tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(nu))
        prog = gamma_gemm(32, 32, 32, tile=8, units=units)
        t0 = time.perf_counter()
        res = simulate(ag, prog)
        dt = time.perf_counter() - t0
        rows.append({"name": f"gamma/units{nu}", "us_per_call": dt * 1e6,
                     "derived": f"cycles={res.cycles}"})


def bench_aidg(rows: List[Dict]) -> None:
    """AIDG vs event sim: error % and speedup (larger instance)."""
    A = np.ones((64, 64), np.float32)
    ag, _ = make_gamma_ag(n_units=4)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = tuple((f"lsu{k}", f"matMulFu{k}", f"vrf{k}") for k in range(4))
    prog = gamma_gemm(64, 64, 64, tile=8, units=units)

    t0 = time.perf_counter()
    sim_cycles = simulate(ag, prog).cycles
    t_sim = time.perf_counter() - t0

    trace = build_trace(ag, prog)
    aidg = build_aidg(ag, trace)
    t0 = time.perf_counter()
    est = longest_path_fixed_point(aidg).max()
    t_est = time.perf_counter() - t0

    err = abs(est - sim_cycles) / sim_cycles * 100
    rows.append({"name": "aidg/gamma64_u4", "us_per_call": t_est * 1e6,
                 "derived": (f"err_pct={err:.2f};speedup={t_sim / max(t_est, 1e-9):.1f}x;"
                             f"sim_cycles={sim_cycles};aidg={est:.0f}")})


def bench_eyeriss(rows: List[Dict]) -> None:
    """Eyeriss-derived row-stationary conv (paper §6 references [26])."""
    import numpy as np
    from repro.core.archs import make_eyeriss_ag
    from repro.core.mapping.conv import (eyeriss_conv2d, init_conv_memory,
                                         read_conv_result)
    rng = np.random.default_rng(0)
    ifm = rng.normal(size=(16, 18))
    flt = rng.normal(size=(3, 3))
    for cols in (2, 4):
        ag, _ = make_eyeriss_ag(rows=4, columns=cols)
        init_conv_memory(ag, ifm, flt)
        prog = eyeriss_conv2d(16, 18, 3, 3, 4, cols)
        t0 = time.perf_counter()
        res = simulate(ag, prog)
        dt = time.perf_counter() - t0
        rows.append({"name": f"eyeriss/conv16x18_c{cols}",
                     "us_per_call": dt * 1e6,
                     "derived": f"cycles={res.cycles}"})


def bench_plasticine(rows: List[Dict]) -> None:
    """Plasticine-derived parallel patterns (paper §6 references [27])."""
    import numpy as np
    from repro.core.archs import make_plasticine_ag
    from repro.core.mapping.patterns import (init_vector_memory,
                                             plasticine_map_reduce)
    x = np.random.default_rng(0).normal(size=(4096,))
    for n in (2, 4):
        ag, _ = make_plasticine_ag(n_pcu=n, n_pmu=n)
        init_vector_memory(ag, x, n)
        prog = plasticine_map_reduce(4096, n, n)
        t0 = time.perf_counter()
        res = simulate(ag, prog)
        dt = time.perf_counter() - t0
        rows.append({"name": f"plasticine/mapreduce4k_p{n}",
                     "us_per_call": dt * 1e6,
                     "derived": f"cycles={res.cycles}"})


def run(rows: List[Dict]) -> None:
    bench_oma_gemm(rows)
    bench_systolic(rows)
    bench_gamma(rows)
    bench_eyeriss(rows)
    bench_plasticine(rows)
    bench_aidg(rows)
