"""Serving benchmark (ROADMAP item 1): DSE-as-a-service throughput.

Sections:

* ``serve/throughput`` — N concurrent clients firing a mixed query
  stream (full-matrix, arch-subset, override and top-k queries, each
  distinct question repeated) at one :class:`repro.serve.DSEService`;
  reports end-to-end queries/s plus how the micro-batcher coalesced the
  stream (windows, device dispatches, mean batch size) and the
  device-side configs/s actually evaluated.  The small-budget run also
  replays the same stream sequentially and asserts the threaded answers
  are identical — determinism under concurrency, measured live.
* ``serve/cache-hit`` — the same run's answer-cache counters
  (hits / misses / coalesced and the combined hit ratio).  The
  small-budget run asserts the ratio is > 0 (a repeated question must
  never reach the device twice).
* ``serve/surrogate`` — the staged oracle hierarchy's fast tier: the
  SAME cold distinct-query stream served twice, once by a service whose
  every query the trained surrogate answers and once by the packed-only
  service; reports per-fresh-query latency for both tiers, the speedup
  (asserted ≥ 10x on the small budget), the surrogate's training time,
  and the measured fallback rate at the default confidence threshold.
  The small-budget row is guarded against the recorded snapshot
  (``benchmarks.baseline``), so fast-tier throughput regressions fail CI.
* ``serve/degraded`` — graceful degradation throughput: the same cold
  distinct-query stream served while the circuit breaker is latched
  open (packed dispatch failing by fault plan, half-open probe out of
  reach), so every answer comes from the surrogate tier stamped
  ``tier="surrogate-degraded"`` with its widened bound.  The
  small-budget row is guarded against the recorded snapshot — a
  regression in degraded-mode throughput means the failure path got
  slower, exactly when it matters.
* ``serve/recovery`` — the full chaos arc measured end to end: a finite
  fault window (transient dispatch errors) trips the breaker mid-
  stream, queries degrade, then the shed -> half-open-probe walk is
  timed until the first exact ``tier="packed"`` answer comes back;
  reports breaker opens/sheds, degraded/failed counts, probe count and
  time-to-recovery.
* ``serve/sharded`` — ``PackedMatrix.evaluate(sharded=True)`` vs the
  single-device path on the same candidate batch: devices used, both
  throughputs, speedup, and bitwise agreement (always asserted).  When
  the process only sees one device, the probe re-runs itself in a
  subprocess under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  so the sharded code path is always exercised.  The > 2x speedup floor
  is asserted only when the host has >= 8 physical cores — forced host
  devices on fewer cores time-slice the same silicon, so the speedup is
  real parallelism there, not on a 1-core CI box.

Budget: ``BENCH_BUDGET=small`` shrinks the pool / stream (same code
paths); rows are recorded via ``python -m benchmarks.run --only serve
--json`` into ``BENCH_serve.json``.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List

import numpy as np

SMALL = os.environ.get("BENCH_BUDGET", "").lower() == "small"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _query_stream(ex) -> List:
    """A deterministic client workload derived from the served matrix:
    per-workload full-matrix / top-k / override queries plus per-arch
    subset queries — the distinct questions a cache-hit run repeats."""
    from repro.serve import Query

    workloads = sorted({cs.workload for cs in ex.compiled})
    archs = sorted({cs.arch for cs in ex.compiled})
    knob = ex.space.names[0]
    qs = []
    for w in workloads:
        qs.append(Query.make(workload=w))
        qs.append(Query.make(workload=w, top_k=3))
        qs.append(Query.make(workload=w, overrides={knob: 2.0}))
    for a in archs:
        qs.append(Query.make(archs=[a]))
    return qs


def _bench_service(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer
    from repro.serve import DSEService

    ex = Explorer()                    # packed engine, operator matrix
    pool = 32 if SMALL else 128
    reps = 3 if SMALL else 8
    distinct = _query_stream(ex)
    stream = distinct * reps
    # chunk=pool pads every stacked window to ONE compiled batch shape,
    # so variable window composition never re-traces mid-run
    kw = dict(pool=pool, chunk=pool, max_batch=8, window_s=0.005)

    # warm pass: compiles the fixed-shape dispatch + scenario kernels
    with DSEService(ex, **kw) as warm:
        warm.query_many(distinct)

    svc = DSEService(ex, **kw)         # fresh answer cache, warm jit cache
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=8) as tp:
        answers = list(tp.map(svc.query, stream))
    dt = time.perf_counter() - t0
    st = svc.stats()
    svc.close()

    n = len(stream)
    cs = st["cache"]
    configs = st["dispatched_candidates"] * st["cells"]
    rows.append({"name": "serve/throughput", "us_per_call": dt / n * 1e6,
                 "derived": (f"clients=8;queries={n};"
                             f"distinct={len(distinct)};pool={pool};"
                             f"cells={st['cells']};"
                             f"queries_per_s={n / dt:.0f};"
                             f"windows={st['windows']};"
                             f"device_dispatches={st['device_dispatches']};"
                             f"mean_batch={st['mean_batch']:.2f};"
                             f"configs_per_s={configs / dt:.0f}")})
    rows.append({"name": "serve/cache-hit", "us_per_call": dt / n * 1e6,
                 "derived": (f"hits={cs['hits']};misses={cs['misses']};"
                             f"coalesced={cs['coalesced']};"
                             f"hit_ratio={st['hit_ratio']:.3f}")})
    if SMALL and st["hit_ratio"] <= 0.0:
        raise AssertionError(
            f"answer cache never hit over {n} queries "
            f"({len(distinct)} distinct): {cs}")
    if cs["hits"] + cs["coalesced"] + cs["misses"] != n:
        raise AssertionError(f"cache counters {cs} do not account for "
                             f"all {n} queries")

    if SMALL:
        # determinism under concurrency, asserted live: the threaded
        # answers must equal a sequential replay of the same stream
        with DSEService(ex, **kw) as ref_svc:
            ref = ref_svc.query_many(stream)
        if answers != ref:
            bad = [i for i, (a, b) in enumerate(zip(answers, ref))
                   if a != b]
            raise AssertionError(
                f"threaded answers diverge from sequential replay at "
                f"stream positions {bad[:5]}")


# -- the staged oracle hierarchy's fast tier ---------------------------------

def _bench_surrogate(rows: List[Dict]):
    """Benches the fast tier; returns ``(explorer, bundle)`` so the
    fault-path benches reuse the trained surrogate instead of paying for
    training twice."""
    from repro.core.aidg.explorer import Explorer
    from repro.serve import DSEService
    from repro.surrogate import SurrogateConfig, train_surrogate

    ex = Explorer()                    # packed engine, operator matrix
    cfg = SurrogateConfig(n_samples=96 if SMALL else 192,
                          steps=600 if SMALL else 1500)
    t0 = time.perf_counter()
    bundle = train_surrogate(ex, cfg)
    t_train = time.perf_counter() - t0

    pool = 32 if SMALL else 128
    kw = dict(pool=pool, chunk=pool, max_batch=8)
    distinct = _query_stream(ex)
    n = len(distinct)

    # warm both tiers' compiled shapes, then time COLD sequential streams
    # on fresh services: every query is a miss, so the per-query cost is
    # the tier's evaluation itself, not the answer cache
    with DSEService(ex, surrogate=bundle, surrogate_max_err=np.inf,
                    **kw) as warm:
        warm.query_many(distinct)
    with DSEService(ex, **kw) as warm:
        warm.query_many(distinct)

    def cold_run(**extra):
        svc = DSEService(ex, **kw, **extra)
        t0 = time.perf_counter()
        answers = svc.query_many(distinct)
        dt = time.perf_counter() - t0
        st = svc.stats()
        svc.close()
        return dt, st, answers

    t_sur, st_sur, a_sur = cold_run(surrogate=bundle,
                                    surrogate_max_err=np.inf)
    t_pkd, st_pkd, _ = cold_run()
    if st_sur["tiers"]["surrogate"] != n or st_pkd["tiers"]["packed"] != n:
        raise AssertionError(
            f"tier routing broke the cold streams: {st_sur['tiers']} / "
            f"{st_pkd['tiers']} for {n} distinct queries")

    # the honest fallback rate at the DEFAULT confidence threshold
    _, st_def, _ = cold_run(surrogate=bundle)
    speedup = t_pkd / t_sur
    configs = n * pool * st_sur["cells"]
    rows.append({"name": "serve/surrogate", "us_per_call": t_sur / n * 1e6,
                 "derived": (f"queries={n};pool={pool};"
                             f"cells={st_sur['cells']};"
                             f"sur_us_per_query={t_sur / n * 1e6:.0f};"
                             f"packed_us_per_query={t_pkd / n * 1e6:.0f};"
                             f"speedup={speedup:.1f}x;"
                             f"configs_per_s={configs / t_sur:.0f};"
                             f"train_s={t_train:.1f};"
                             f"fallback_rate={st_def['fallback_rate']:.2f};"
                             f"max_err={st_def['surrogate_max_err']}")})
    if SMALL and speedup < 10.0:
        raise AssertionError(
            f"surrogate tier speedup {speedup:.1f}x < 10x over the packed "
            f"dispatch ({t_sur / n * 1e6:.0f}us vs {t_pkd / n * 1e6:.0f}us "
            f"per query)")
    if SMALL:
        for a in a_sur:
            if a.tier != "surrogate" or a.err_bound <= 0.0:
                raise AssertionError(
                    f"cold surrogate stream produced a {a.tier!r} answer "
                    f"(err_bound={a.err_bound})")
    return ex, bundle


# -- the failure path: degraded throughput + chaos recovery ------------------

def _bench_faults(rows: List[Dict], ex, bundle) -> None:
    from repro.serve import (CircuitBreaker, DEGRADED_WIDEN, DSEService,
                             Query, RetryPolicy, ServeError)

    pool = 32 if SMALL else 128
    kw = dict(pool=pool, chunk=pool, max_batch=8,
              surrogate=bundle, surrogate_max_err=-1.0,  # packed routing
              degraded_max_err=np.inf)
    distinct = _query_stream(ex)
    n = len(distinct)

    # -- serve/degraded: breaker latched open, every cold query answered
    # by the surrogate with its widened bound
    def latched():
        return DSEService(
            ex, **kw, retry=RetryPolicy(max_attempts=1, base_s=0.0),
            breaker=CircuitBreaker(open_after=1, probe_after=10 ** 9),
            fault_plan="packed[0]=error")

    with latched() as warm:               # compile the surrogate shapes
        warm.query_many(distinct, return_exceptions=True)
    svc = latched()
    t0 = time.perf_counter()
    answers = svc.query_many(distinct)
    t_deg = time.perf_counter() - t0
    st = svc.stats()
    svc.close()
    if SMALL:
        for a in answers:
            if a.tier != "surrogate-degraded" or a.err_bound <= 0.0:
                raise AssertionError(
                    f"latched-breaker stream produced a {a.tier!r} answer "
                    f"(err_bound={a.err_bound})")
        if st["tiers"]["surrogate-degraded"] != n:
            raise AssertionError(
                f"degraded tier accounted {st['tiers']} for {n} queries")
    configs = n * pool * st["cells"]
    rows.append({"name": "serve/degraded", "us_per_call": t_deg / n * 1e6,
                 "derived": (f"queries={n};pool={pool};"
                             f"cells={st['cells']};"
                             f"deg_us_per_query={t_deg / n * 1e6:.0f};"
                             f"configs_per_s={configs / t_deg:.0f};"
                             f"widen={DEGRADED_WIDEN};"
                             f"breaker={st['breaker']['state']};"
                             f"breaker_shed={st['breaker']['shed']}")})

    # -- serve/recovery: a finite fault window trips the breaker, then
    # the shed -> probe walk is timed until packed answers return
    plan = "packed[0:3]=error"
    svc = DSEService(ex, **kw,
                     retry=RetryPolicy(max_attempts=1, base_s=0.0),
                     breaker=CircuitBreaker(open_after=1, probe_after=1),
                     fault_plan=plan)
    t0 = time.perf_counter()
    outcomes = svc.query_many(distinct, return_exceptions=True)
    probe = Query.make(workload=distinct[0].workload, top_k=17)
    probes, recovered = 0, None
    for _ in range(16):
        # rejected opportunities come back as DEGRADED answers here (the
        # surrogate covers everything), so walk until the first exact one
        probes += 1
        try:
            out = svc.query_many([probe])[0]
        except ServeError:
            continue
        if out.tier == "packed":
            recovered = out
            break
    t_rec = time.perf_counter() - t0
    st = svc.stats()
    svc.close()
    if len(outcomes) != n:
        raise AssertionError(
            f"{n} queries submitted under chaos, {len(outcomes)} resolved")
    if recovered is None or recovered.tier != "packed":
        raise AssertionError(
            f"breaker never recovered to the packed tier under {plan!r} "
            f"(state {st['breaker']['state']})")
    if SMALL and st["breaker"]["opens"] < 1:
        raise AssertionError(f"fault window {plan!r} never tripped the "
                             f"breaker")
    degraded = sum(1 for o in outcomes
                   if not isinstance(o, BaseException)
                   and o.tier == "surrogate-degraded")
    rows.append({"name": "serve/recovery",
                 "us_per_call": t_rec / (n + probes) * 1e6,
                 "derived": (f"queries={n};plan={plan.replace(';', '|')};"
                             f"degraded={degraded};"
                             f"opens={st['breaker']['opens']};"
                             f"breaker_shed={st['breaker']['shed']};"
                             f"probes={probes};"
                             f"retries={st['retries']};"
                             f"recovered_tier={recovered.tier};"
                             f"recovery_ms={t_rec * 1e3:.1f}")})


# -- sharded probe ----------------------------------------------------------

def _sharded_payload() -> Dict:
    """Single-device vs candidate-sharded PackedMatrix throughput under
    whatever device count THIS process sees; runs in the bench process
    when it already has multiple devices, or in the forced-8-device
    subprocess below."""
    import jax

    from repro.core.aidg.explorer import Explorer, random_candidates

    ex = Explorer()
    pm = ex.packed_matrix()
    D = pm.n_shards(None)
    B = -(-(64 if SMALL else 512) // D) * D
    cand = random_candidates(ex.space, B, seed=0)

    def best_of(fn, reps=3):
        fn()                           # warm-up / compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_single = best_of(lambda: pm.evaluate(cand))
    t_shard = best_of(lambda: pm.evaluate(cand, sharded=True))
    exact = bool(np.array_equal(pm.evaluate(cand),
                                pm.evaluate(cand, sharded=True)))
    configs = B * pm.n_cells
    return {"devices": D, "batch": B, "cells": int(pm.n_cells),
            "single_configs_per_s": configs / t_single,
            "sharded_configs_per_s": configs / t_shard,
            "speedup": t_single / t_shard, "exact": exact,
            "jax_devices": jax.local_device_count()}


def _sharded_probe_subprocess(n_devices: int = 8) -> Dict:
    """Re-run :func:`_sharded_payload` in a child process with
    ``--xla_force_host_platform_device_count`` set (the flag only takes
    effect before the first jax import, so the parent can't apply it to
    itself); the child prints the payload as its last stdout line."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags + " " if flags else "") + \
        f"--xla_force_host_platform_device_count={n_devices}"
    src = str(REPO_ROOT / "src")
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serve", "--sharded-probe"],
        cwd=str(REPO_ROOT), env=env, capture_output=True, text=True,
        timeout=1200)
    if proc.returncode != 0:
        raise AssertionError(
            f"sharded probe subprocess failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _bench_sharded(rows: List[Dict]) -> None:
    import jax

    if jax.local_device_count() > 1:
        payload = _sharded_payload()
    else:
        payload = _sharded_probe_subprocess(8)
    rows.append({"name": "serve/sharded", "us_per_call": 0.0,
                 "derived": (f"devices={payload['devices']};"
                             f"batch={payload['batch']};"
                             f"cells={payload['cells']};"
                             f"single_configs_per_s="
                             f"{payload['single_configs_per_s']:.0f};"
                             f"sharded_configs_per_s="
                             f"{payload['sharded_configs_per_s']:.0f};"
                             f"speedup={payload['speedup']:.2f}x;"
                             f"exact={payload['exact']};"
                             f"host_cores={os.cpu_count()}")})
    if not payload["exact"]:
        raise AssertionError(
            "sharded evaluation is not bitwise-equal to single-device")
    cores = os.cpu_count() or 1
    if cores >= 8 and payload["speedup"] < 2.0:
        # forced host devices only parallelize when cores back them; on
        # a >= 8-core host a sub-2x sharded path is a real regression
        raise AssertionError(
            f"sharded speedup {payload['speedup']:.2f}x < 2x on "
            f"{payload['devices']} devices / {cores} cores")


def run(rows: List[Dict]) -> None:
    _bench_service(rows)
    ex, bundle = _bench_surrogate(rows)
    _bench_faults(rows, ex, bundle)
    _bench_sharded(rows)
    from .baseline import assert_baseline, guard_enabled
    if guard_enabled():
        assert_baseline(rows, section="serve",
                        names=("serve/surrogate", "serve/degraded"))


if __name__ == "__main__":
    if "--sharded-probe" in sys.argv:
        print(json.dumps(_sharded_payload()))
    else:
        rows: List[Dict] = []
        run(rows)
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
