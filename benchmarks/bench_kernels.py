"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-path
timing, not TPU performance — TPU perf is the §Roofline story)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(rows: List[Dict]) -> None:
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    dt = _time(lambda a, b: ops.maxplus_matmul(a, b, bm=64, bk=64, bn=64), A, B)
    rows.append({"name": "kernel/maxplus_256", "us_per_call": dt * 1e6,
                 "derived": "interpret=True"})

    Ab = A.astype(jnp.bfloat16); Bb = B.astype(jnp.bfloat16)
    dt = _time(lambda a, b: ops.gemm(a, b, bm=64, bk=64, bn=64), Ab, Bb)
    rows.append({"name": "kernel/systolic_gemm_256", "us_per_call": dt * 1e6,
                 "derived": "interpret=True;bf16"})

    q = jnp.asarray(rng.normal(size=(4, 256, 64)), jnp.float32)
    dt = _time(lambda x: ops.flash_attention(x, x, x, bq=64, bk=64), q)
    rows.append({"name": "kernel/flash_attn_256", "us_per_call": dt * 1e6,
                 "derived": "interpret=True"})
