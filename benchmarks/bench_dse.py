"""DSE benchmark (§1/§7 motivation): candidate accelerators per second via
the vmapped max-plus sweep — the co-design inner loop.

Two sections:

* ``dse/sweep256`` — the single-scenario sweep (one Γ̈ GEMM AIDG, 256 θ),
  the seed benchmark kept for trajectory continuity.
* ``dse/matrix`` — the batched multi-architecture engine: the full default
  scenario matrix x >= 1000 shared-knob candidates in one process, plus the
  measured speedup over per-config event simulation (the paper's
  cycle-accurate oracle), obtained by timing the event simulator on each
  scenario once and extrapolating to the same config count.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.acadl.sim import build_trace
from repro.core.aidg import build_aidg, make_problem, sweep
from repro.core.archs import make_gamma_ag
from repro.core.mapping.gemm import gamma_gemm, init_gemm_memory


def _bench_single(rows: List[Dict]) -> None:
    A = np.ones((32, 32), np.float32)
    ag, _ = make_gamma_ag(n_units=2)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(32, 32, 32, tile=8, units=units)
    trace = build_trace(ag, prog)
    prob = make_problem(build_aidg(ag, trace))

    rng = np.random.default_rng(0)
    B = 256
    to = rng.uniform(0.25, 4.0, (B, prob.n_op)).astype(np.float32)
    ts = rng.uniform(0.25, 4.0, (B, prob.n_st)).astype(np.float32)
    out = sweep(prob, to, ts)          # warm-up + compile
    t0 = time.perf_counter()
    out = sweep(prob, to, ts)
    dt = time.perf_counter() - t0
    best = int(np.argmin(out))
    rows.append({"name": "dse/sweep256", "us_per_call": dt / B * 1e6,
                 "derived": (f"designs_per_s={B / dt:.0f};"
                             f"best_cycles={out[best]:.0f};"
                             f"range={out.min():.0f}-{out.max():.0f}")})


def _bench_matrix(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer, random_candidates

    ex = Explorer()
    S = len(ex.compiled)
    B = 1024
    cand = random_candidates(ex.space, B, seed=0)
    ex.explore(cand)                   # warm-up: compile per scenario at (B,)
    t0 = time.perf_counter()
    res = ex.explore(cand)
    dt = time.perf_counter() - t0
    configs = B * S
    batched_cps = configs / dt

    # oracle cost: one event simulation per scenario, extrapolated to the
    # same (candidate x scenario) config count
    sim_total = 0.0
    for cs in ex.compiled:
        t0 = time.perf_counter()
        cs.simulate()
        sim_total += time.perf_counter() - t0
    sim_cps = S / sim_total            # event-sim configs per second
    speedup = batched_cps / sim_cps

    rows.append({"name": "dse/matrix", "us_per_call": dt / configs * 1e6,
                 "derived": (f"scenarios={S};candidates={B};"
                             f"configs_per_s={batched_cps:.0f};"
                             f"eventsim_configs_per_s={sim_cps:.2f};"
                             f"speedup_vs_eventsim={speedup:.0f}x;"
                             f"pareto={len(res.pareto)}")})


def run(rows: List[Dict]) -> None:
    _bench_single(rows)
    _bench_matrix(rows)
