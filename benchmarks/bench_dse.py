"""DSE benchmark (§1/§7 motivation): candidate accelerators per second via
the vmapped max-plus sweep — the co-design inner loop."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.acadl.sim import build_trace
from repro.core.aidg import build_aidg, make_problem, sweep
from repro.core.archs import make_gamma_ag
from repro.core.mapping.gemm import gamma_gemm, init_gemm_memory


def run(rows: List[Dict]) -> None:
    A = np.ones((32, 32), np.float32)
    ag, _ = make_gamma_ag(n_units=2)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(32, 32, 32, tile=8, units=units)
    trace = build_trace(ag, prog)
    prob = make_problem(build_aidg(ag, trace))

    rng = np.random.default_rng(0)
    B = 256
    to = rng.uniform(0.25, 4.0, (B, prob.n_op)).astype(np.float32)
    ts = rng.uniform(0.25, 4.0, (B, prob.n_st)).astype(np.float32)
    out = sweep(prob, to, ts)          # warm-up + compile
    t0 = time.perf_counter()
    out = sweep(prob, to, ts)
    dt = time.perf_counter() - t0
    best = int(np.argmin(out))
    rows.append({"name": "dse/sweep256", "us_per_call": dt / B * 1e6,
                 "derived": (f"designs_per_s={B / dt:.0f};"
                             f"best_cycles={out[best]:.0f};"
                             f"range={out.min():.0f}-{out.max():.0f}")})
