"""DSE benchmark (§1/§7 motivation): candidate accelerators per second via
the vmapped max-plus sweep — the co-design inner loop.

Sections:

* ``dse/sweep256`` — the single-scenario sweep (one Γ̈ GEMM AIDG, 256 θ),
  the seed benchmark kept for trajectory continuity.
* ``dse/matrix`` — the batched multi-architecture engine with the per-node
  ``scan`` engine (the pre-compile-pipeline baseline): the full default
  scenario matrix x the candidate batch in one process, plus the measured
  speedup over per-config event simulation (the paper's cycle-accurate
  oracle), obtained by timing the event simulator on each scenario once and
  extrapolating to the same config count.
* ``dse/wavefront`` — the same batch through the level-scheduled wavefront
  engine (per-cell): sequential depth per sweep is the DAG's critical
  depth instead of its node count.  Also asserts both engines agree.
* ``dse/packed`` — the condensed + matrix-packed engine (the Explorer
  default): the WHOLE scenario/network matrix (operator cells + every
  default network cell) chain-condensed, padded into shape buckets, and
  evaluated cells x candidates in ONE jitted dispatch per batch
  (``repro.core.aidg.dse.PackedMatrix``).  Asserts θ = 1 agreement with
  the per-cell wavefront engine and the event-sim oracle per cell, and
  (small budget) that packed throughput is at least the per-cell
  wavefront row's.
* ``aidg/depth-vs-n`` — per-scenario level-schedule statistics: node count
  vs critical depth, i.e. how much sequential work the compile pipeline
  (trace → AIDG → LevelSchedule → CompiledAIDG) removes.
* ``dse/gradient`` — the gradient-based co-design loop: batched multi-start
  projected Adam over the smooth max-plus relaxation
  (``repro.core.aidg.gradient``) vs random search *and* coordinate descent
  at their respective candidate budgets, on the latency·cost objective.
  The small-budget run asserts the gradient incumbent beats random search
  at an equal candidate budget.
* ``network/matrix`` — the whole-network matrix (``repro.core.network``):
  every default (architecture, DNN) cell evaluated end-to-end per
  candidate, vs the per-cell event-sim oracle (each unique tile program
  simulated once, memoized across cells, then max-plus composed — the
  same composition the estimate uses).  The small-budget run asserts
  ≥ 20x throughput over the oracle.

Budget: set ``BENCH_BUDGET=small`` for a CI-smoke run (few candidates, same
code paths, loose throughput sanity asserted so evaluator regressions fail
loudly).

Recorded-baseline guard: on the smoke tier (or when
``BENCH_BASELINE_GUARD=1``), the live ``dse/packed`` and
``network/matrix`` rows are additionally ratio-compared against the
checked-in budget-matched snapshot (``BENCH_dse_small.json`` /
``BENCH_dse.json``) via :func:`benchmarks.baseline.assert_baseline` —
an absolute floor on serving-path throughput, not just the relative
engine-vs-engine floors above.  Default tolerance 0.5x
(``BENCH_BASELINE_TOL`` overrides), so an injected 2x slowdown fails.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.core.acadl.sim import build_trace
from repro.core.aidg import build_aidg, make_problem, sweep
from repro.core.archs import make_gamma_ag
from repro.core.mapping.gemm import gamma_gemm, init_gemm_memory

SMALL = os.environ.get("BENCH_BUDGET", "").lower() == "small"


def _bench_single(rows: List[Dict]) -> None:
    A = np.ones((32, 32), np.float32)
    ag, _ = make_gamma_ag(n_units=2)
    init_gemm_memory(ag, A, A, memory="dram0", tile=8)
    units = (("lsu0", "matMulFu0", "vrf0"), ("lsu1", "matMulFu1", "vrf1"))
    prog = gamma_gemm(32, 32, 32, tile=8, units=units)
    trace = build_trace(ag, prog)
    prob = make_problem(build_aidg(ag, trace))

    rng = np.random.default_rng(0)
    B = 64 if SMALL else 256
    to = rng.uniform(0.25, 4.0, (B, prob.n_op)).astype(np.float32)
    ts = rng.uniform(0.25, 4.0, (B, prob.n_st)).astype(np.float32)
    out = sweep(prob, to, ts)          # warm-up + compile
    t0 = time.perf_counter()
    out = sweep(prob, to, ts)
    dt = time.perf_counter() - t0
    best = int(np.argmin(out))
    rows.append({"name": "dse/sweep256", "us_per_call": dt / B * 1e6,
                 "derived": (f"designs_per_s={B / dt:.0f};"
                             f"best_cycles={out[best]:.0f};"
                             f"range={out.min():.0f}-{out.max():.0f}")})


def _time_explore(ex, cand, reps: int = 3):
    """(best wall time, last result): best-of-N because shared hosts are
    noisy; the result is reused so callers don't re-sweep."""
    res = ex.explore(cand)             # warm-up: compile per scenario at (B,)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = ex.explore(cand)
        best = min(best, time.perf_counter() - t0)
    return best, res


def _bench_matrix(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer, random_candidates

    # both explorers share the process-wide AIDG cache; only the compiled
    # sweep kernels differ (cached per (problem, n_iters, engine))
    ex_scan = Explorer(engine="scan")
    ex_wave = Explorer(engine="wavefront")
    S = len(ex_scan.compiled)
    B = 64 if SMALL else 1024
    cand = random_candidates(ex_scan.space, B, seed=0)
    configs = B * S

    dt_scan, res_scan = _time_explore(ex_scan, cand)
    dt_wave, res_wave = _time_explore(ex_wave, cand)
    if not np.allclose(res_scan.cycles, res_wave.cycles, atol=0.5):
        raise AssertionError("wavefront and scan engines disagree: "
                             f"max |Δ| = "
                             f"{np.abs(res_scan.cycles - res_wave.cycles).max()}")
    scan_cps = configs / dt_scan
    wave_cps = configs / dt_wave

    # oracle cost: one event simulation per scenario, extrapolated to the
    # same (candidate x scenario) config count
    sim_total = 0.0
    for cs in ex_scan.compiled:
        t0 = time.perf_counter()
        cs.simulate()
        sim_total += time.perf_counter() - t0
    sim_cps = S / sim_total            # event-sim configs per second

    rows.append({"name": "dse/matrix", "us_per_call": dt_scan / configs * 1e6,
                 "derived": (f"scenarios={S};candidates={B};engine=scan;"
                             f"configs_per_s={scan_cps:.0f};"
                             f"eventsim_configs_per_s={sim_cps:.2f};"
                             f"speedup_vs_eventsim={scan_cps / sim_cps:.0f}x;"
                             f"pareto={len(res_scan.pareto)}")})
    rows.append({"name": "dse/wavefront",
                 "us_per_call": dt_wave / configs * 1e6,
                 "derived": (f"scenarios={S};candidates={B};"
                             f"engine=wavefront;"
                             f"configs_per_s={wave_cps:.0f};"
                             f"speedup_vs_scan={wave_cps / scan_cps:.2f}x;"
                             f"speedup_vs_eventsim={wave_cps / sim_cps:.0f}x")})
    if SMALL and wave_cps < 0.3 * scan_cps:
        # loose floor: host noise can shrink the win, but an order-of-
        # magnitude wavefront regression must fail the smoke run
        raise AssertionError(
            f"wavefront engine regressed: {wave_cps:.0f} configs/s vs "
            f"scan {scan_cps:.0f}")
    _bench_packed(rows, ex_wave, cand, wave_cps, sim_cps)


def _bench_packed(rows: List[Dict], ex_wave, cand, wave_cps: float,
                  sim_cps: float) -> None:
    from repro.core.aidg.explorer import Explorer

    # the packed engine's natural scope is the WHOLE scenario/network
    # matrix: every operator cell plus every default (arch, DNN) cell,
    # chain-condensed and evaluated in one dispatch per batch — repeated
    # tile programs across network cells are deduplicated into shared rows
    ex_packed = Explorer(networks=True)        # engine="packed" default
    S = len(ex_packed.compiled)
    B = cand.shape[0]
    configs = B * S
    dt, res = _time_explore(ex_packed, cand)
    packed_cps = configs / dt
    pm = ex_packed.packed_matrix()
    st = pm.stats()

    # θ = 1 engine agreement: packed == per-cell wavefront (exact) and
    # within each cell's sim_tol of the event-sim oracle — run on the
    # operator cells (their oracle is cheap and already simulated above)
    theta1 = ex_packed.evaluate(
        np.ones((1, ex_packed.space.n), np.float32))[0]
    for k, cs in enumerate(ex_wave.compiled):
        est = float(ex_wave.baselines[k])
        pk = float(theta1[k])
        if abs(pk - est) > 0.5:
            raise AssertionError(
                f"packed/wavefront θ=1 disagreement on {cs.name}: "
                f"{pk} vs {est}")
        sim = cs.simulate()
        tol = max(cs.scenario.sim_tol, 1e-9)
        if abs(pk - sim) / sim > tol:
            raise AssertionError(
                f"packed θ=1 vs event-sim on {cs.name}: {pk} vs {sim}")

    rows.append({"name": "dse/packed", "us_per_call": dt / configs * 1e6,
                 "derived": (f"cells={S};candidates={B};engine=packed;"
                             f"rows={st['rows']};buckets={st['buckets']};"
                             f"levels={st['levels']}->"
                             f"{st['levels_condensed']}"
                             f"({st['level_reduction']:.1f}x);"
                             f"configs_per_s={packed_cps:.0f};"
                             f"speedup_vs_wavefront="
                             f"{packed_cps / wave_cps:.2f}x;"
                             f"speedup_vs_eventsim="
                             f"{packed_cps / sim_cps:.0f}x")})
    if SMALL and packed_cps < wave_cps:
        raise AssertionError(
            f"packed matrix engine regressed: {packed_cps:.0f} configs/s "
            f"is under the per-cell wavefront row ({wave_cps:.0f})")
    _bench_energy(rows, ex_packed, cand, configs)


def _bench_energy(rows: List[Dict], ex_packed, cand,
                  configs: int) -> None:
    """``dse/energy``: the 3-objective dispatch — (cycles, energy) from
    the SAME compiled tuple function as the cycles-only path, so adding
    energy must cost ~nothing.  Also asserts the packed energy (folded
    through the condensed chains) matches a per-cell recompute from the
    raw op-class counts at θ = 1, on every cell."""
    S = len(ex_packed.compiled)
    B = cand.shape[0]

    def _best_of(fn, reps=3):
        fn(cand)                       # warm-up (shared compiled kernel)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(cand)
            best = min(best, time.perf_counter() - t0)
        return best

    dt_c = _best_of(ex_packed.evaluate)
    dt_e = _best_of(ex_packed.evaluate_full)
    energy_cps = configs / dt_e
    overhead = dt_e / dt_c

    # θ = 1 exactness: packed (condensed-chain fold) vs per-cell analytic
    # recompute from raw op-class counts, every cell
    theta1 = np.ones((1, ex_packed.space.n), np.float32)
    c1, e1 = ex_packed.evaluate_full(theta1)
    edyn, pstat = ex_packed._energy_arrays()
    e_ref = edyn.sum(axis=1) + pstat * c1[0].astype(np.float64)
    rel = np.abs(e1[0] - e_ref) / np.maximum(e_ref, 1.0)
    if rel.max() > 1e-3:
        k = int(np.argmax(rel))
        raise AssertionError(
            f"packed θ=1 energy vs per-cell recompute on "
            f"{ex_packed.compiled[k].name}: {e1[0, k]:.6g} vs "
            f"{e_ref[k]:.6g}")

    rows.append({"name": "dse/energy", "us_per_call": dt_e / configs * 1e6,
                 "derived": (f"cells={S};candidates={B};"
                             f"objectives=cycles+energy;"
                             f"configs_per_s={energy_cps:.0f};"
                             f"overhead_vs_cycles_only={overhead:.3f}x;"
                             f"max_theta1_relerr={rel.max():.2e}")})
    if SMALL and overhead > 1.15:
        raise AssertionError(
            f"energy objective is no longer free: evaluate_full took "
            f"{overhead:.2f}x the cycles-only dispatch (floor 1.15x)")


def _bench_depth(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer

    ex = Explorer()                    # AIDGs already cached by _bench_matrix
    stats = ex.level_stats()
    ratios = [s["n"] / s["levels"] for s in stats]
    deepest = max(stats, key=lambda s: s["levels"])
    widest = max(stats, key=lambda s: s["parallelism"])
    clv = sum(s["levels_condensed"] for s in stats)
    rows.append({"name": "aidg/depth-vs-n", "us_per_call": 0.0,
                 "derived": (f"scenarios={len(stats)};"
                             f"total_nodes={sum(s['n'] for s in stats)};"
                             f"total_levels={sum(s['levels'] for s in stats)};"
                             f"total_levels_condensed={clv};"
                             f"mean_parallelism={np.mean(ratios):.2f};"
                             f"max_parallelism={max(ratios):.1f}"
                             f"({widest['name']});"
                             f"deepest={deepest['name']}"
                             f"={deepest['levels']}lv->"
                             f"{deepest['levels_condensed']}lv")})


def _bench_gradient(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer, random_candidates
    from repro.core.aidg.gradient import GradientExplorer

    ex = Explorer()                    # AIDGs already cached
    ge = GradientExplorer(ex)

    kw = (dict(starts=2, steps=6, lr=0.3, tau0=0.3, tau_min=0.03) if SMALL
          else {})                     # full defaults: starts=2, steps=22
    # warm-up: one 1-step refine at the same start count traces the
    # per-scenario grad kernels and the hard-finish evaluator, so the
    # timed run below measures evaluation throughput, not trace time
    # (matching how every other row in this file warms up first)
    ge.refine(**{**kw, "steps": 1})
    t0 = time.perf_counter()
    res = ge.refine(**kw)
    dt_grad = time.perf_counter() - t0
    grad_score = res.score
    budget = res.evaluations

    # random search at the SAME candidate budget (row 0 is θ = 1, so the
    # baseline machine is always among the candidates)
    cand = random_candidates(ex.space, budget, seed=0)
    r = ex.explore(cand)
    rand_score = float((r.latency * r.cost).min())

    # coordinate descent at ITS default budget ((points+1) x knobs x rounds)
    if SMALL:
        cd_rounds, cd_points = 1, 3
    else:
        cd_rounds, cd_points = 2, 9
    t0 = time.perf_counter()
    cd_theta = ex.refine(rounds=cd_rounds, points=cd_points)
    dt_cd = time.perf_counter() - t0
    rr = ex.explore(cd_theta[None, :])
    cd_score = float(rr.latency[0] * rr.cost[0])
    cd_budget = (cd_points + 1) * ex.space.n * cd_rounds

    rows.append({"name": "dse/gradient",
                 "us_per_call": dt_grad / budget * 1e6,
                 "derived": (f"evals={budget};score={grad_score:.4f};"
                             f"random_score_same_budget={rand_score:.4f};"
                             f"coord_score={cd_score:.4f}"
                             f"(evals={cd_budget},{dt_cd:.1f}s);"
                             f"starts={len(res.final_scores)};"
                             f"steps={len(res.history)};"
                             f"tau={res.history[0]['tau']:.2f}->"
                             f"{res.history[-1]['tau']:.2f}")})
    if SMALL and grad_score >= rand_score:
        raise AssertionError(
            f"gradient refine regressed: score {grad_score:.4f} at "
            f"{budget} evals does not beat random search "
            f"({rand_score:.4f} at the same budget)")


def _bench_network(rows: List[Dict]) -> None:
    from repro.core.aidg.explorer import Explorer, random_candidates
    from repro.core.network import default_network_scenarios

    ex = Explorer(scenarios=default_network_scenarios())   # packed default
    S = len(ex.compiled)
    layers = sum(cn.n_layers for cn in ex.compiled)
    instances = sum(cn.stack.instances for cn in ex.compiled)
    B = 32 if SMALL else 256
    cand = random_candidates(ex.space, B, seed=0)
    configs = B * S

    dt, res = _time_explore(ex, cand)
    net_cps = configs / dt
    # the pre-packing path: one stacked sweep per network cell (repeated
    # tile programs re-evaluated per cell) — the packed engine's dedup is
    # most visible here
    ex_pc = Explorer(scenarios=default_network_scenarios(),
                     engine="wavefront")
    dt_pc, _ = _time_explore(ex_pc, cand)
    percell_cps = configs / dt_pc

    # oracle cost per cell: every unique tile program simulated once
    # (memoized across cells — tile programs are shared through the AIDG
    # cache, and the oracle gets the same reuse the estimator gets), then
    # composed analytically
    sim_total = 0.0
    tile_sims: Dict[int, float] = {}
    for cn in ex.compiled:
        t0 = time.perf_counter()
        for cell in cn.cells:
            if id(cell) not in tile_sims:
                tile_sims[id(cell)] = cell.simulate()
        sim_total += time.perf_counter() - t0
    sim_cps = S / sim_total

    best = int(np.argmin(res.latency))
    rows.append({"name": "network/matrix", "us_per_call": dt / configs * 1e6,
                 "derived": (f"cells={S};candidates={B};"
                             f"unique_layers={layers};"
                             f"instances={instances:.0f};"
                             f"engine=packed;"
                             f"configs_per_s={net_cps:.0f};"
                             f"percell_configs_per_s={percell_cps:.0f};"
                             f"speedup_vs_percell="
                             f"{net_cps / percell_cps:.2f}x;"
                             f"eventsim_configs_per_s={sim_cps:.2f};"
                             f"speedup_vs_eventsim={net_cps / sim_cps:.0f}x;"
                             f"best_latency={res.latency[best]:.3f}")})
    if SMALL and net_cps < 20.0 * sim_cps:
        raise AssertionError(
            f"network sweep throughput regressed: {net_cps:.1f} configs/s "
            f"is under 20x the event-sim oracle ({sim_cps:.2f}/s)")
    if SMALL and net_cps < percell_cps:
        raise AssertionError(
            f"packed network sweep regressed: {net_cps:.1f} configs/s "
            f"is under the per-cell path ({percell_cps:.1f}/s)")


def run(rows: List[Dict]) -> None:
    _bench_single(rows)
    _bench_matrix(rows)
    _bench_depth(rows)
    _bench_gradient(rows)
    _bench_network(rows)
    from .baseline import assert_baseline, guard_enabled
    if guard_enabled():
        assert_baseline(rows, section="dse")
